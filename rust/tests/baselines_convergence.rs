//! Cross-system integration: every §7.1 baseline solves the same problem to
//! a common loose gap, and the Figure-1 *ordering mechanisms* hold at test
//! scale — pSCOPE's per-epoch communication is constant while the
//! minibatch methods' grows with n, and DBCD needs orders of magnitude
//! more simulated time (Table 2's mechanism).

use pscope::baselines::{
    all_baselines, dbcd::Dbcd, pscope::PScope, BaselineOpts, DistSolver,
};
use pscope::config::Model;
use pscope::data::synth;
use pscope::loss::{Objective, Reg};
use pscope::net::NetModel;
use pscope::optim::fista::reference_optimum;

fn problem() -> (pscope::data::Dataset, Reg, f64) {
    let ds = synth::tiny(55).with_n(400).generate();
    let reg = Reg { lam1: 1e-3, lam2: 1e-3 };
    let obj = Objective::new(&ds, Model::Logistic.loss(), reg);
    let opt = reference_optimum(&obj, 30_000);
    (ds, reg, opt.objective)
}

#[test]
fn all_baselines_reach_loose_gap() {
    let (ds, reg, p_star) = problem();
    for solver in all_baselines() {
        let opts = BaselineOpts {
            p: 4,
            seed: 42,
            max_rounds: 600,
            max_total_s: 120.0,
            net: NetModel::zero(),
            record_every: 10,
            target_objective: p_star,
            tol: 1e-2,
        };
        let trace = solver.run(&ds, Model::Logistic, reg, &opts);
        let best = trace
            .points
            .iter()
            .map(|p| p.objective - p_star)
            .fold(f64::INFINITY, f64::min);
        assert!(
            best < 2e-2,
            "{} never reached the loose gap (best {best:.3e})",
            solver.name()
        );
    }
}

#[test]
fn pscope_comm_is_constant_per_epoch_vs_minibatch_linear() {
    let (ds, reg, _) = problem();
    let run_bytes = |solver: &dyn DistSolver, rounds: usize| {
        let opts = BaselineOpts {
            p: 4,
            seed: 42,
            max_rounds: rounds,
            max_total_s: 300.0,
            net: NetModel::zero(),
            record_every: 1,
            target_objective: f64::NEG_INFINITY,
            tol: 0.0,
        };
        solver
            .run(&ds, Model::Logistic, reg, &opts)
            .points
            .last()
            .unwrap()
            .comm_bytes as f64
    };
    let ps = run_bytes(&PScope::default(), 3) / 3.0;
    // batch 4 => n/(b*p) = 25 parameter-server rounds per epoch
    let sgd = run_bytes(&pscope::baselines::dpsgd::DpSgd { batch: 4, t0: 2000.0 }, 3) / 3.0;
    // dpSGD moves ~steps_per_epoch x the bytes pSCOPE moves per epoch
    assert!(
        sgd > 8.0 * ps,
        "expected dpSGD per-epoch comm >> pSCOPE ({sgd:.0} vs {ps:.0})"
    );
}

#[test]
fn dbcd_needs_far_more_communication() {
    // Table 2's *mechanism*, stated scale-robustly: DBCD moves O(n)-sized
    // vectors for many rounds (direction exchange + every line-search
    // trial), while pSCOPE moves 4 d-sized vectors per epoch. At the
    // paper's n = 581k..677k this communication gap is what produces the
    // 100-1000x wall-time ratios; here we assert the byte ratio directly
    // (the wall-time ordering at full scale is reproduced by
    // `cargo bench --bench table2_dbcd`).
    // geometry matters: the paper's datasets all have n >> d (rcv1:
    // 677k x 47k), which is exactly when DBCD's n-sized rounds lose to
    // pSCOPE's d-sized ones. Mirror that ratio at test scale.
    let ds = synth::SynthSpec {
        name: "nd10".into(),
        n: 12_000,
        d: 1_200,
        nnz_per_row: 30.0,
        powerlaw_alpha: 1.0,
        k_true: 100,
        label_noise: 0.05,
        class_scale: 1.0,
        task: synth::Task::Classification,
        seed: 77,
    }
    .generate();
    let reg = Reg { lam1: 1e-4, lam2: 1e-5 };
    let obj = Objective::new(&ds, Model::Logistic.loss(), reg);
    let p_star = reference_optimum(&obj, 4000).objective;
    let bytes_to = |solver: &dyn DistSolver| -> Option<u64> {
        let opts = BaselineOpts {
            p: 4,
            seed: 42,
            max_rounds: 50_000,
            max_total_s: 20.0,
            net: NetModel::ten_gbe(),
            record_every: 1,
            target_objective: p_star,
            tol: 1e-3,
        };
        let tr = solver.run(&ds, Model::Logistic, reg, &opts);
        tr.points
            .iter()
            .find(|pt| pt.objective - p_star <= 1e-3)
            .map(|pt| pt.comm_bytes)
    };
    let b_ps = bytes_to(&PScope::default()).expect("pSCOPE must reach 1e-3");
    match bytes_to(&Dbcd::default()) {
        Some(b_db) => assert!(
            b_db > 3 * b_ps,
            "Table-2 mechanism violated: DBCD {b_db}B vs pSCOPE {b_ps}B to the same gap"
        ),
        None => { /* never reached the gap inside the budget — also Table-2 shape */ }
    }
}

#[test]
fn lasso_flavor_runs_on_all_instance_distributed_baselines() {
    let ds = synth::tiny(56)
        .with_n(300)
        .with_task(synth::Task::Regression)
        .generate();
    let reg = Reg { lam1: 1e-3, lam2: 1e-3 };
    let obj = Objective::new(&ds, Model::Lasso.loss(), reg);
    let p_star = reference_optimum(&obj, 30_000).objective;
    for solver in all_baselines() {
        let opts = BaselineOpts {
            p: 3,
            seed: 1,
            max_rounds: 400,
            max_total_s: 60.0,
            net: NetModel::zero(),
            record_every: 10,
            target_objective: p_star,
            tol: 1e-2,
        };
        let trace = solver.run(&ds, Model::Lasso, reg, &opts);
        let best = trace
            .points
            .iter()
            .map(|p| p.objective - p_star)
            .fold(f64::INFINITY, f64::min);
        assert!(
            best < 5e-2,
            "{} failed on lasso (best gap {best:.3e})",
            solver.name()
        );
    }
}
