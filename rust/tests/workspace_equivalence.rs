//! The zero-allocation contract of the epoch workspace (DESIGN.md §6):
//!
//! 1. reusing one `EpochWorkspace` across many epochs is **bit-identical**
//!    to the fresh-allocation path (generation stamping never leaks state
//!    between epochs);
//! 2. the deterministic blocked shard gradient is **bit-exact** at every
//!    thread count (the reduction tree is fixed by the block size, not the
//!    parallelism);
//! 3. after the first epoch at a given geometry the workspace performs
//!    **zero further allocations** (the `LazyStats`-style counter stays
//!    flat) — the steady-state training loop does no per-epoch heap work.

use pscope::config::{Model, PscopeConfig};
use pscope::coordinator::train_with;
use pscope::data::synth;
use pscope::loss::{Loss, Objective, Reg, GRAD_BLOCK_ROWS};
use pscope::net::NetModel;
use pscope::optim::lazy::{lazy_inner_epoch, lazy_inner_epoch_ws, LazyStats};
use pscope::optim::scope::{scope_inner_epoch, scope_inner_epoch_ws};
use pscope::optim::svrg::{dense_inner_epoch, dense_inner_epoch_ws};
use pscope::optim::workspace::EpochWorkspace;
use pscope::partition::Partitioner;
use pscope::rng::Rng;

/// 4-epoch chained training run through the legacy fresh-alloc entry point.
fn chain_fresh(
    ds: &pscope::data::Dataset,
    obj: &Objective<'_>,
    eta: f64,
    reg: Reg,
    m: usize,
    epochs: usize,
) -> Vec<Vec<f64>> {
    let mut w = vec![0.0; ds.d()];
    let mut rng = Rng::new(31);
    let mut stats = LazyStats::default();
    let mut iterates = Vec::new();
    for _ in 0..epochs {
        let z = obj.data_grad(&w);
        w = lazy_inner_epoch(ds, Loss::Logistic, &w, &z, eta, reg, m, &mut rng, &mut stats);
        iterates.push(w.clone());
    }
    iterates
}

#[test]
fn workspace_reuse_is_bit_identical_lazy() {
    let ds = synth::rcv1_like(9).with_n(500).generate();
    let reg = Reg { lam1: 1e-4, lam2: 1e-4 };
    let obj = Objective::new(&ds, Loss::Logistic, reg);
    let eta = 0.4 / obj.smoothness();
    let m = ds.n();
    let epochs = 4;
    let fresh = chain_fresh(&ds, &obj, eta, reg, m, epochs);

    let mut w = vec![0.0; ds.d()];
    let mut rng = Rng::new(31);
    let mut stats = LazyStats::default();
    let mut ws = EpochWorkspace::new();
    for want in fresh.iter().take(epochs) {
        let z = obj.data_grad(&w);
        let u = lazy_inner_epoch_ws(
            &ds, Loss::Logistic, &w, &z, eta, reg, m, &mut rng, &mut stats, &mut ws,
        );
        assert_eq!(u, want.as_slice(), "workspace reuse diverged");
        w.copy_from_slice(u);
    }
}

#[test]
fn workspace_reuse_is_bit_identical_dense() {
    let ds = synth::tiny(10).generate();
    let reg = Reg { lam1: 1e-3, lam2: 1e-3 };
    let obj = Objective::new(&ds, Loss::Logistic, reg);
    let eta = 0.3 / obj.smoothness();
    let m = 2 * ds.n();

    let mut w1 = vec![0.0; ds.d()];
    let mut r1 = Rng::new(8);
    let mut w2 = w1.clone();
    let mut r2 = Rng::new(8);
    let mut ws = EpochWorkspace::new();
    for _ in 0..3 {
        let z1 = obj.data_grad(&w1);
        w1 = dense_inner_epoch(&ds, Loss::Logistic, &w1, &z1, eta, reg, m, &mut r1);
        let z2 = obj.data_grad(&w2);
        let u = dense_inner_epoch_ws(
            &ds, Loss::Logistic, &w2, &z2, eta, reg, m, &mut r2, &mut ws,
        );
        assert_eq!(u, w1.as_slice(), "dense workspace reuse diverged");
        w2.copy_from_slice(u);
    }
}

#[test]
fn workspace_reuse_is_bit_identical_scope_correction() {
    // the c > 0 path exercises the z-shift scratch buffer
    let ds = synth::tiny(11).generate();
    let reg = Reg { lam1: 1e-3, lam2: 1e-3 };
    let obj = Objective::new(&ds, Loss::Logistic, reg);
    let eta = 0.2 / obj.smoothness();
    let c = 0.5 * obj.smoothness();
    let w = vec![0.01; ds.d()];
    let z = obj.data_grad(&w);
    let mut ws = EpochWorkspace::new();
    for seed in [1u64, 2, 3] {
        let mut r1 = Rng::new(seed);
        let mut r2 = Rng::new(seed);
        let a = scope_inner_epoch(
            &ds, Loss::Logistic, &w, &z, eta, reg, c, 150, &mut r1,
            &mut Default::default(),
        );
        let b = scope_inner_epoch_ws(
            &ds, Loss::Logistic, &w, &z, eta, reg, c, 150, &mut r2,
            &mut Default::default(), &mut ws,
        );
        assert_eq!(a.as_slice(), b, "scope-correction workspace path diverged");
    }
}

#[test]
fn steady_state_performs_no_allocations() {
    // the LazyStats-style counter: after the warm-up epoch, reuse adds zero
    let ds = synth::rcv1_like(12).with_n(400).generate();
    let reg = Reg { lam1: 1e-4, lam2: 1e-4 };
    let obj = Objective::new(&ds, Loss::Logistic, reg);
    let eta = 0.4 / obj.smoothness();
    let mut w = vec![0.0; ds.d()];
    let mut rng = Rng::new(5);
    let mut stats = LazyStats::default();
    let mut ws = EpochWorkspace::new();

    let z = obj.data_grad(&w);
    let u = lazy_inner_epoch_ws(
        &ds, Loss::Logistic, &w, &z, eta, reg, ds.n(), &mut rng, &mut stats, &mut ws,
    );
    w.copy_from_slice(u);
    let warm = ws.allocations();
    assert!(warm > 0, "warm-up should have sized the buffers");

    for _ in 0..5 {
        let z = obj.data_grad(&w);
        let u = lazy_inner_epoch_ws(
            &ds, Loss::Logistic, &w, &z, eta, reg, ds.n(), &mut rng, &mut stats, &mut ws,
        );
        w.copy_from_slice(u);
    }
    assert_eq!(
        ws.allocations(),
        warm,
        "steady-state epochs must not allocate workspace buffers"
    );

    // the worker gradient path shares the same workspace and is also flat
    let g1 = ws.shard_grad_sum(&obj, &w, 1).to_vec();
    let after_grad = ws.allocations();
    for _ in 0..3 {
        let g = ws.shard_grad_sum(&obj, &w, 1);
        assert_eq!(g, g1.as_slice());
    }
    assert_eq!(ws.allocations(), after_grad);
}

#[test]
fn threaded_gradient_path_allocations_flat() {
    // multi-block + threads: the block-partial scratch grows once (and is
    // counted), then every further pass is allocation-free
    let ds = synth::rcv1_like(15).with_n(2 * GRAD_BLOCK_ROWS + 100).generate();
    let reg = Reg { lam1: 1e-4, lam2: 1e-4 };
    let obj = Objective::new(&ds, Loss::Logistic, reg);
    let w = vec![0.02; ds.d()];
    let mut ws = EpochWorkspace::new();
    let g1 = ws.shard_grad_sum(&obj, &w, 3).to_vec();
    let warm = ws.allocations();
    assert!(warm >= 2, "grad buffer and partials growth must both be counted, got {warm}");
    for _ in 0..3 {
        assert_eq!(ws.shard_grad_sum(&obj, &w, 3), g1.as_slice());
    }
    assert_eq!(ws.allocations(), warm, "threaded gradient passes must not allocate");
}

#[test]
fn parallel_data_grad_bit_exact_across_thread_counts() {
    // n spans several reduction blocks so real merging happens; 7 threads
    // exceeds the block count and must clamp without changing the tree
    let n = 4 * GRAD_BLOCK_ROWS + GRAD_BLOCK_ROWS / 3;
    let ds = synth::rcv1_like(13).with_n(n).generate();
    let reg = Reg { lam1: 1e-5, lam2: 1e-5 };
    let obj = Objective::new(&ds, Loss::Logistic, reg);
    let mut rng = Rng::new(17);
    let w: Vec<f64> = (0..ds.d()).map(|_| 0.05 * rng.normal()).collect();

    let serial = obj.data_grad(&w); // threads = 1 reference
    let mut scratch = Vec::new();
    for threads in [1usize, 2, 4, 7] {
        let mut g = vec![0.0; ds.d()];
        obj.data_grad_into_threaded(&w, &mut g, threads, &mut scratch);
        assert_eq!(serial, g, "data_grad diverged at {threads} threads");
        let mut gs = vec![0.0; ds.d()];
        obj.shard_grad_sum_into(&w, &mut gs, threads, &mut scratch);
        // same scaling op as data_grad_into (one multiply by weight/n)
        let factor = obj.weight / ds.n() as f64;
        for v in gs.iter_mut() {
            *v *= factor;
        }
        assert_eq!(serial, gs, "shard sum tree diverged at {threads} threads");
    }
}

#[test]
fn coordinator_trajectory_independent_of_grad_threads() {
    // end-to-end: the worker epoch path must be bit-identical whether the
    // epoch-start gradient pass runs on 1 thread or several
    let ds = synth::rcv1_like(14).with_n(2 * GRAD_BLOCK_ROWS + 200).generate();
    let reg = Reg { lam1: 1e-4, lam2: 1e-5 };
    let run = |grad_threads: usize| {
        let cfg = PscopeConfig {
            p: 2,
            outer_iters: 3,
            reg,
            seed: 42,
            grad_threads,
            ..PscopeConfig::for_dataset("rcv1_like", Model::Logistic)
        };
        let part = Partitioner::Uniform.split(&ds, 2, 3);
        train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap().w
    };
    let w1 = run(1);
    for t in [2usize, 3] {
        assert_eq!(w1, run(t), "grad_threads={t} perturbed the trajectory");
    }
}
