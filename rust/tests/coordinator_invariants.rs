//! Property tests on the CALL coordinator and partitioners:
//! routing/batching/state invariants (the L3 contract).

use pscope::config::{Model, PscopeConfig, WorkerBackend};
use pscope::coordinator::protocol::{vec_bytes, MSG_HEADER_BYTES};
use pscope::coordinator::train_with;
use pscope::data::synth::{self, SynthSpec, Task};
use pscope::loss::Reg;
use pscope::net::NetModel;
use pscope::partition::Partitioner;
use pscope::rng::Rng;
use pscope::testkit::prop;

fn random_ds(rng: &mut Rng, shrink: u32) -> pscope::data::Dataset {
    let scale = 1usize << shrink.min(3);
    SynthSpec {
        name: "prop".into(),
        n: (60 + rng.below(200)) / scale + 10,
        d: (20 + rng.below(60)) / scale + 5,
        nnz_per_row: 4.0 + rng.f64() * 6.0,
        powerlaw_alpha: 0.7,
        k_true: 8,
        label_noise: 0.05,
        class_scale: 1.0,
        task: Task::Classification,
        seed: rng.next_u64(),
    }
    .generate()
}

#[test]
fn prop_partitions_route_every_instance_exactly_once() {
    prop::check("disjoint partitions cover", 40, |rng, shrink| {
        let ds = random_ds(rng, shrink);
        let p = 1 + rng.below(9);
        let seed = rng.next_u64();
        for strat in [
            Partitioner::Uniform,
            Partitioner::LabelSkew75,
            Partitioner::LabelSeparated,
            Partitioner::Engineered,
        ] {
            let part = strat.split(&ds, p, seed);
            if !part.is_disjoint_cover(ds.n()) {
                return prop::that(false, format!("{} p={p} not a disjoint cover", part.tag));
            }
        }
        let rep = Partitioner::Replicated.split(&ds, p, seed);
        prop::that(
            rep.total_assigned() == p * ds.n(),
            format!("replicated assigned {} != {}", rep.total_assigned(), p * ds.n()),
        )
    });
}

#[test]
fn prop_training_is_deterministic_in_seed() {
    prop::check("coordinator deterministic", 10, |rng, shrink| {
        let ds = random_ds(rng, shrink);
        let p = 1 + rng.below(5);
        let cfg = PscopeConfig {
            p,
            outer_iters: 3,
            reg: Reg { lam1: 1e-3, lam2: 1e-3 },
            seed: rng.next_u64(),
            ..PscopeConfig::for_dataset("prop", Model::Logistic)
        };
        let part = Partitioner::Uniform.split(&ds, p, 3);
        let a = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap();
        let b = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap();
        prop::that(
            a.w == b.w && a.comm == b.comm,
            format!("nondeterministic run: p={p} seed={}", cfg.seed),
        )
    });
}

#[test]
fn prop_comm_bytes_match_protocol_formula() {
    // per epoch: p * (Broadcast + ShardGrad + FullGrad + LocalIterate)
    prop::check("comm accounting exact", 15, |rng, shrink| {
        let ds = random_ds(rng, shrink);
        let p = 1 + rng.below(5);
        let epochs = 1 + rng.below(4);
        let cfg = PscopeConfig {
            p,
            outer_iters: epochs,
            reg: Reg { lam1: 1e-3, lam2: 1e-3 },
            seed: 1,
            ..PscopeConfig::for_dataset("prop", Model::Logistic)
        };
        let part = Partitioner::Uniform.split(&ds, p, 3);
        let out = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap();
        let d = ds.d();
        let per_epoch = p as u64
            * (vec_bytes(d)            // Broadcast
                + (vec_bytes(d) + 8)   // ShardGrad
                + vec_bytes(d)         // FullGrad
                + (vec_bytes(d) + 16)); // LocalIterate
        let expect = epochs as u64 * per_epoch + p as u64 * MSG_HEADER_BYTES; // + Stop
        prop::that(
            out.comm.0 == expect,
            format!("bytes {} != expected {expect} (p={p} epochs={epochs} d={d})", out.comm.0),
        )
    });
}

#[test]
fn prop_sparse_and_dense_backends_agree() {
    prop::check("backend equivalence", 10, |rng, shrink| {
        let ds = random_ds(rng, shrink);
        let p = 1 + rng.below(4);
        let mk = |backend| PscopeConfig {
            p,
            outer_iters: 3,
            reg: Reg { lam1: 5e-3, lam2: 2e-3 },
            seed: 77,
            backend,
            ..PscopeConfig::for_dataset("prop", Model::Logistic)
        };
        let part = Partitioner::Uniform.split(&ds, p, 5);
        let a = train_with(&ds, &part, &mk(WorkerBackend::RustSparse), None, NetModel::zero())
            .unwrap();
        let b = train_with(&ds, &part, &mk(WorkerBackend::RustDense), None, NetModel::zero())
            .unwrap();
        for j in 0..ds.d() {
            if (a.w[j] - b.w[j]).abs() > 1e-9 * (1.0 + a.w[j].abs()) {
                return prop::that(
                    false,
                    format!("coord {j}: sparse {} vs dense {}", a.w[j], b.w[j]),
                );
            }
        }
        prop::that(true, "")
    });
}

#[test]
fn prop_monotone_objective_over_epochs() {
    // pSCOPE is not strictly monotone, but from a cold start with a sane
    // step it must not *increase* the objective by more than noise, and
    // must strictly decrease it overall.
    prop::check("objective decreases", 15, |rng, shrink| {
        let ds = random_ds(rng, shrink);
        let cfg = PscopeConfig {
            p: 1 + rng.below(4),
            outer_iters: 6,
            reg: Reg { lam1: 1e-3, lam2: 1e-3 },
            seed: rng.next_u64(),
            ..PscopeConfig::for_dataset("prop", Model::Logistic)
        };
        let part = Partitioner::Uniform.split(&ds, cfg.p, 9);
        let out = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap();
        let first = out.trace.points.first().unwrap().objective;
        let last = out.trace.last_objective();
        prop::that(last < first, format!("no progress: {first} -> {last}"))
    });
}

#[test]
fn panicking_worker_surfaces_as_error_not_hang() {
    // A worker that dies mid-epoch must turn into Err(..) on the caller's
    // thread — not a deadlocked reduce loop, not a propagated panic.
    // eta * lam1 >= 1 trips the engine's `assert!(decay > 0.0)` inside every
    // worker thread after the ShardGrad exchange, i.e. genuinely mid-epoch.
    let ds = synth::tiny(46).generate();
    let cfg = PscopeConfig {
        p: 3,
        outer_iters: 2,
        eta: 50.0,
        m_inner: 10,
        reg: Reg { lam1: 1.0, lam2: 1e-3 },
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 3, 1);
    let start = std::time::Instant::now();
    let result = train_with(&ds, &part, &cfg, None, NetModel::zero());
    let err = result.expect_err("worker panic must surface as Err");
    assert!(
        start.elapsed() < std::time::Duration::from_secs(30),
        "coordinator took too long to notice the dead worker"
    );
    let msg = format!("{err}");
    assert!(
        msg.contains("panicked") || msg.contains("died"),
        "unexpected error: {msg}"
    );
}

#[test]
fn empty_shard_rejected_without_spawning() {
    // p > n uniform splits can produce empty shards; the coordinator must
    // refuse them up front rather than hang a worker with no data.
    let ds = synth::tiny(47).generate();
    let part = pscope::partition::Partition {
        assignment: vec![(0..ds.n()).collect(), Vec::new(), Vec::new()],
        tag: "two_empty".into(),
    };
    let cfg = PscopeConfig {
        p: 3,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let err = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap_err();
    assert!(format!("{err}").contains("empty shard"), "{err}");
}

#[test]
fn replicated_partition_beats_separated_on_skewed_data() {
    // Figure-2(b) shape at integration scale. Two ingredients put the run
    // in the regime Theorem 2 is about (see the fig2b bench and the
    // SynthSpec::class_scale field docs):
    // class-conditional curvature (class_scale > 1 — real datasets have
    // it, symmetric synthetic data does not) and inner epochs long enough
    // that workers approach their local optima, so the averaged iterate
    // feels the local-global gap.
    let ds = synth::tiny(33).with_n(2000).with_class_scale(3.0).generate();
    let reg = Reg { lam1: 1e-4, lam2: 1e-5 };
    let run = |strat: Partitioner| {
        let cfg = PscopeConfig {
            p: 4,
            outer_iters: 15,
            m_inner: 10_000,
            c_eta: 1.0,
            reg,
            seed: 42,
            ..PscopeConfig::for_dataset("tiny", Model::Logistic)
        };
        let part = strat.split(&ds, 4, 3);
        train_with(&ds, &part, &cfg, None, NetModel::zero())
            .unwrap()
            .trace
            .last_objective()
    };
    let star = run(Partitioner::Replicated);
    let sep = run(Partitioner::LabelSeparated);
    assert!(
        star < sep - 1e-9,
        "pi* ({star}) should converge strictly faster than pi3 ({sep})"
    );
}
