//! Runtime integration: the AOT artifacts (python/jax/pallas -> HLO text)
//! loaded and executed through PJRT must agree with the rust reference
//! computation — the rust half of the interchange contract (the python
//! half lives in python/tests/test_aot.py).
//!
//! Requires `make artifacts`; every test self-skips when missing.

use pscope::data::synth;
use pscope::loss::{Loss, Objective, Reg};
use pscope::rng::Rng;
use pscope::runtime::{Input, Manifest, XlaRuntime};

fn runtime() -> Option<XlaRuntime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    match XlaRuntime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            // manifest present but no PJRT client (built without `xla`)
            eprintln!("skipping: {e}");
            None
        }
    }
}

// ---- missing-artifact degradation (runs with or without `make artifacts`,
// with or without the `xla` feature) ------------------------------------

#[test]
fn missing_manifest_is_clear_error_not_panic() {
    let err = Manifest::load("no-such-artifacts/manifest.json").unwrap_err();
    let msg = format!("{err}");
    assert!(msg.starts_with("manifest:"), "wrong layer: {msg}");
    assert!(msg.contains("make artifacts"), "not actionable: {msg}");
}

#[test]
fn missing_artifact_dir_fails_runtime_open_cleanly() {
    let err = XlaRuntime::open("no-such-artifacts").unwrap_err();
    assert!(!format!("{err}").is_empty());
}

#[test]
fn xla_backend_without_artifacts_errors_before_training() {
    // the coordinator must surface the missing manifest as Err(..) on the
    // caller's thread — before any worker thread exists, so no hang and no
    // worker-side panic.
    let ds = synth::tiny(61).generate();
    let cfg = pscope::config::PscopeConfig {
        p: 2,
        outer_iters: 2,
        backend: pscope::config::WorkerBackend::Xla,
        ..pscope::config::PscopeConfig::for_dataset("tiny", pscope::config::Model::Logistic)
    };
    let part = pscope::partition::Partitioner::Uniform.split(&ds, 2, 1);
    let err = pscope::coordinator::train_with(
        &ds,
        &part,
        &cfg,
        Some("no-such-artifacts".into()),
        pscope::net::NetModel::zero(),
    )
    .unwrap_err();
    assert!(format!("{err}").contains("make artifacts"), "{err}");
}

#[test]
fn xla_backend_without_artifact_dir_is_config_error() {
    let ds = synth::tiny(62).generate();
    let cfg = pscope::config::PscopeConfig {
        p: 2,
        backend: pscope::config::WorkerBackend::Xla,
        ..pscope::config::PscopeConfig::for_dataset("tiny", pscope::config::Model::Logistic)
    };
    let part = pscope::partition::Partitioner::Uniform.split(&ds, 2, 1);
    let err = pscope::coordinator::train_with(
        &ds,
        &part,
        &cfg,
        None,
        pscope::net::NetModel::zero(),
    )
    .unwrap_err();
    assert!(format!("{err}").contains("artifact dir"), "{err}");
}

/// Dense random problem matching an artifact (n, d) config.
fn dense_problem(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * d)
        .map(|_| (rng.normal() / (d as f64).sqrt()) as f32)
        .collect();
    let y: Vec<f32> = (0..n)
        .map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 })
        .collect();
    let w: Vec<f32> = (0..d).map(|_| (0.1 * rng.normal()) as f32).collect();
    (x, y, w)
}

#[test]
fn manifest_lists_all_programs() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest().programs().len(), 20);
    for model in ["logistic", "lasso"] {
        for kind in ["shard_grad", "shard_loss", "inner_epoch", "prox_full_step"] {
            assert!(
                rt.manifest().programs().iter().any(|p| p.kind == kind && p.model == model),
                "missing {kind}/{model}"
            );
        }
    }
}

#[test]
fn shard_grad_matches_rust() {
    let Some(rt) = runtime() else { return };
    for model in ["logistic", "lasso"] {
        let (n, d) = (256usize, 64usize);
        let (x, y, w) = dense_problem(n, d, 3);
        let outs = rt
            .execute(
                &format!("shard_grad_{model}_{n}x{d}"),
                &[Input::F32(&x, &[n, d]), Input::F32(&y, &[n]), Input::F32(&w, &[d])],
            )
            .unwrap();
        // rust reference
        let loss = if model == "logistic" { Loss::Logistic } else { Loss::Squared };
        let mut want = vec![0.0f64; d];
        for i in 0..n {
            let a: f64 = (0..d).map(|j| x[i * d + j] as f64 * w[j] as f64).sum();
            let c = loss.hprime(a, y[i] as f64);
            for j in 0..d {
                want[j] += c * x[i * d + j] as f64;
            }
        }
        for j in 0..d {
            assert!(
                (outs[0][j] as f64 - want[j]).abs() < 1e-3 * (1.0 + want[j].abs()),
                "{model} coord {j}: {} vs {}",
                outs[0][j],
                want[j]
            );
        }
    }
}

#[test]
fn shard_loss_matches_rust() {
    let Some(rt) = runtime() else { return };
    for model in ["logistic", "lasso"] {
        let (n, d) = (256usize, 64usize);
        let (x, y, w) = dense_problem(n, d, 4);
        let outs = rt
            .execute(
                &format!("shard_loss_{model}_{n}x{d}"),
                &[Input::F32(&x, &[n, d]), Input::F32(&y, &[n]), Input::F32(&w, &[d])],
            )
            .unwrap();
        let loss = if model == "logistic" { Loss::Logistic } else { Loss::Squared };
        let mut want = 0.0f64;
        for i in 0..n {
            let a: f64 = (0..d).map(|j| x[i * d + j] as f64 * w[j] as f64).sum();
            want += loss.h(a, y[i] as f64);
        }
        let got = outs[0][0] as f64;
        assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "{model}: {got} vs {want}");
    }
}

#[test]
fn inner_epoch_matches_rust_engine() {
    let Some(rt) = runtime() else { return };
    let (n, d, m) = (256usize, 64usize, 64usize);
    for model in ["logistic", "lasso"] {
        let (x, y, w) = dense_problem(n, d, 5);
        let mut rng = Rng::new(9);
        let idx: Vec<i32> = (0..m).map(|_| rng.below(n) as i32).collect();
        let z: Vec<f32> = (0..d).map(|_| (0.01 * rng.normal()) as f32).collect();
        let (eta, lam1, lam2) = (0.1f32, 1e-3f32, 1e-3f32);
        let scal = [eta, lam1, lam2];
        let outs = rt
            .execute(
                &format!("inner_epoch_{model}_{n}x{d}_m{m}"),
                &[
                    Input::F32(&x, &[n, d]),
                    Input::F32(&y, &[n]),
                    Input::F32(&w, &[d]),
                    Input::F32(&w, &[d]), // u0 = w_t
                    Input::F32(&z, &[d]),
                    Input::I32(&idx, &[m]),
                    Input::F32(&scal, &[3]),
                ],
            )
            .unwrap();
        // rust engine on the same problem, driven by the same index stream:
        // dense_inner_epoch consumes rng.below(n) per step, so rebuild a
        // dataset + rng that replays `idx` exactly via a custom loop.
        let loss = if model == "logistic" { Loss::Logistic } else { Loss::Squared };
        let xd: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let ds = pscope::data::Dataset {
            name: "dense".into(),
            x: pscope::linalg::CsrMatrix::from_dense(n, d, &xd),
            y: y.iter().map(|&v| v as f64).collect(),
        };
        let wt: Vec<f64> = w.iter().map(|&v| v as f64).collect();
        let zd: Vec<f64> = z.iter().map(|&v| v as f64).collect();
        // manual replay of the fused update per sampled index
        let mut u = wt.clone();
        let cw: Vec<f64> = (0..n)
            .map(|i| loss.hprime(ds.x.row(i).dot(&wt), ds.y[i]))
            .collect();
        for &i in &idx {
            let i = i as usize;
            let row = ds.x.row(i);
            let coeff = loss.hprime(row.dot(&u), ds.y[i]) - cw[i];
            let mut xi = vec![0.0f64; d];
            row.axpy_into(1.0, &mut xi);
            pscope::linalg::fused_prox_step_dense(
                &mut u, &xi, &zd, coeff, eta as f64, lam1 as f64, lam2 as f64,
            );
        }
        for j in 0..d {
            assert!(
                (outs[0][j] as f64 - u[j]).abs() < 5e-3 * (1.0 + u[j].abs()),
                "{model} coord {j}: xla {} vs rust {}",
                outs[0][j],
                u[j]
            );
        }
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    let name = "shard_loss_lasso_256x64";
    let a = rt.executable(name).unwrap();
    let b = rt.executable(name).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b), "cache miss on second fetch");
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let x = vec![0f32; 10];
    let err = rt.execute("shard_grad_logistic_256x64", &[Input::F32(&x, &[10])]);
    assert!(err.is_err());
    let (xx, y, w) = dense_problem(256, 64, 1);
    let err = rt.execute(
        "shard_grad_logistic_256x64",
        &[
            Input::F32(&xx, &[256, 64]),
            Input::F32(&w, &[64]), // swapped: y slot gets d-length vector
            Input::F32(&y, &[256]),
        ],
    );
    assert!(err.is_err());
}

#[test]
fn unknown_program_is_manifest_error() {
    let Some(rt) = runtime() else { return };
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn full_coordinator_on_xla_backend_converges() {
    let Some(_) = runtime() else { return };
    let ds = synth::cov_like(42).with_n(1200).generate();
    let reg = Reg { lam1: 1e-3, lam2: 1e-4 };
    let cfg = pscope::config::PscopeConfig {
        p: 2,
        outer_iters: 6,
        reg,
        backend: pscope::config::WorkerBackend::Xla,
        seed: 42,
        ..pscope::config::PscopeConfig::for_dataset("cov_like", pscope::config::Model::Logistic)
    };
    let part = pscope::partition::Partitioner::Uniform.split(&ds, 2, 7);
    let out = pscope::coordinator::train_with(
        &ds,
        &part,
        &cfg,
        Some("artifacts".into()),
        pscope::net::NetModel::zero(),
    )
    .unwrap();
    let obj = Objective::new(&ds, Loss::Logistic, reg);
    let opt = pscope::optim::fista::reference_optimum(&obj, 10_000);
    let gap = out.trace.last_objective() - opt.objective;
    assert!(gap < 1e-4, "xla-backend coordinator gap {gap}");
    // mixed-precision sanity: dense rust backend lands within f32 distance
    let mut cfg2 = cfg.clone();
    cfg2.backend = pscope::config::WorkerBackend::RustDense;
    let out2 = pscope::coordinator::train_with(
        &ds, &part, &cfg2, None, pscope::net::NetModel::zero(),
    )
    .unwrap();
    assert!(
        (out.trace.last_objective() - out2.trace.last_objective()).abs() < 1e-4,
        "backend objectives diverged"
    );
}
