//! Property tests: the §6 recovery-rule engine is semantically identical to
//! the naive dense engine (E6), across randomized problems, regularization
//! regimes (all five Lemma-11 z cases arise naturally), sparsity patterns,
//! and epoch lengths.

use pscope::data::synth::{SynthSpec, Task};
use pscope::loss::{Loss, Objective, Reg};
use pscope::optim::lazy::{lazy_advance, lazy_inner_epoch, LazyStats};
use pscope::optim::svrg::dense_inner_epoch;
use pscope::rng::Rng;
use pscope::testkit::prop;

fn random_spec(rng: &mut Rng, shrink: u32) -> SynthSpec {
    let scale = 1usize << shrink.min(3); // shrink level makes problems smaller
    SynthSpec {
        name: "prop".into(),
        n: (20 + rng.below(120)) / scale + 5,
        d: (10 + rng.below(80)) / scale + 5,
        nnz_per_row: 2.0 + rng.f64() * 6.0,
        powerlaw_alpha: if rng.bool(0.5) { 0.0 } else { 1.1 },
        k_true: 5,
        label_noise: 0.05,
        class_scale: 1.0,
        task: if rng.bool(0.5) { Task::Classification } else { Task::Regression },
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_lazy_epoch_equals_dense_epoch() {
    prop::check("lazy == dense inner epoch", 40, |rng, shrink| {
        let spec = random_spec(rng, shrink);
        let ds = spec.generate();
        let loss = if spec.task == Task::Regression { Loss::Squared } else { Loss::Logistic };
        let reg = Reg {
            lam1: if rng.bool(0.3) { 0.0 } else { rng.f64() * 1e-2 },
            lam2: if rng.bool(0.2) { 0.0 } else { rng.f64() * 1e-2 },
        };
        let obj = Objective::new(&ds, loss, reg);
        let mut w: Vec<f64> = (0..ds.d()).map(|_| 0.2 * rng.normal()).collect();
        if rng.bool(0.3) {
            // exercise the zero-absorbing branch from a zero start
            w.iter_mut().for_each(|v| *v = 0.0);
        }
        let z = obj.data_grad(&w);
        let eta = (0.1 + rng.f64() * 0.5) / obj.smoothness();
        let m = 1 + rng.below(4 * ds.n());
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        let mut stats = LazyStats::default();
        let ud = dense_inner_epoch(&ds, loss, &w, &z, eta, reg, m, &mut r1);
        let ul = lazy_inner_epoch(&ds, loss, &w, &z, eta, reg, m, &mut r2, &mut stats);
        for j in 0..ds.d() {
            let tol = 1e-9 * (1.0 + ud[j].abs());
            if (ud[j] - ul[j]).abs() >= tol {
                return prop::that(
                    false,
                    format!(
                        "spec n={} d={} lam=({:.2e},{:.2e}) eta={eta:.3e} m={m}: coord {j} dense {} vs lazy {}",
                        ds.n(), ds.d(), reg.lam1, reg.lam2, ud[j], ul[j]
                    ),
                );
            }
        }
        prop::that(true, "")
    });
}

#[test]
fn prop_lazy_advance_equals_iteration() {
    prop::check("lazy_advance == repeated map", 300, |rng, _| {
        let u0 = rng.range(-8.0, 8.0);
        let eps = match rng.below(3) {
            0 => 0.0,
            1 => rng.f64() * 1e-3,
            _ => rng.f64() * 0.4,
        };
        let tau = if rng.bool(0.2) { 0.0 } else { rng.f64() * 0.4 };
        // include the boundary cases c = ±tau (Lemma 11 cases 2-3)
        let c = match rng.below(4) {
            0 => tau,
            1 => -tau,
            _ => rng.range(-0.6, 0.6),
        };
        let k = 1 + rng.below(2000);
        let lazy = lazy_advance(u0, k, eps, c, tau);
        let mut naive = u0;
        for _ in 0..k {
            naive = pscope::linalg::soft_threshold((1.0 - eps) * naive - c, tau);
        }
        prop::that(
            (lazy - naive).abs() < 1e-9 * (1.0 + naive.abs()),
            format!("u0={u0} k={k} eps={eps} c={c} tau={tau}: {lazy} vs {naive}"),
        )
    });
}

#[test]
fn prop_savings_match_sparsity() {
    // the counter must report exactly sum(nnz of sampled rows) + d
    prop::check("materialization count exact", 30, |rng, shrink| {
        let spec = random_spec(rng, shrink);
        let ds = spec.generate();
        let loss = Loss::Logistic;
        let reg = Reg { lam1: 1e-3, lam2: 1e-3 };
        let obj = Objective::new(&ds, loss, reg);
        let w = vec![0.0; ds.d()];
        let z = obj.data_grad(&w);
        let m = 1 + rng.below(2 * ds.n());
        let seed = rng.next_u64();
        let mut stats = LazyStats::default();
        let mut r = Rng::new(seed);
        let _ = lazy_inner_epoch(&ds, loss, &w, &z, 0.01, reg, m, &mut r, &mut stats);
        // replay the sampling
        let mut r2 = Rng::new(seed);
        let expect: u64 = (0..m).map(|_| ds.x.row(r2.below(ds.n())).nnz() as u64).sum::<u64>()
            + ds.d() as u64;
        prop::that(
            stats.materializations == expect,
            format!("counted {} expect {expect}", stats.materializations),
        )
    });
}
