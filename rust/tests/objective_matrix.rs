//! The composite-objective scenario matrix: new (loss, regularizer)
//! pairs train end-to-end on both worker engines.
//!
//! For each pair the full CALL coordinator runs and must (a) strictly
//! decrease the objective, (b) close at least half of the initial
//! suboptimality gap against a FISTA reference optimum (FISTA shares the
//! prox dispatch, so it solves the whole matrix), and (c) agree between
//! the lazy and dense paths where both apply:
//!
//! * regularizers **with** the closed-form skip (L1 / elastic net): the
//!   lazy engine runs and must match the dense engine to 1e-9 per epoch;
//! * regularizers **without** one (group Lasso, nonnegative L1): the
//!   sparse backend falls back to the dense engine, pinned **bit for
//!   bit** against an explicit dense-backend run (and reports zero lazy
//!   materializations — proof the fallback actually took the dense path).
//!
//! One TCP-loopback run ships a non-default objective (Huber δ as exact
//! f64 bits + group regularizer) through the RunSpec and must reproduce
//! the in-process trajectory bit for bit — the wire validation of the
//! composite layer, end to end.

use std::time::Duration;

use pscope::config::{Model, PscopeConfig, RegKind, WorkerBackend};
use pscope::coordinator::remote::{serve_worker, MasterEndpoint, RunSpec};
use pscope::coordinator::train_with;
use pscope::data::source::DataSource;
use pscope::data::{synth, Dataset};
use pscope::loss::{Objective, ProxReg, Reg, SmoothLoss};
use pscope::net::NetModel;
use pscope::optim::fista::reference_optimum;
use pscope::partition::Partitioner;
use pscope::rng::Rng;

struct Scenario {
    tag: &'static str,
    ds: Dataset,
    loss: SmoothLoss,
    reg_kind: RegKind,
    reg: Reg,
    has_lazy_skip: bool,
}

/// Four new (loss, regularizer) corners of the matrix — none of them the
/// paper's two original models.
fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            tag: "huber+l1",
            ds: synth::tiny(901).with_task(synth::Task::Regression).generate(),
            loss: SmoothLoss::Huber { delta: 1.0 },
            reg_kind: RegKind::L1,
            reg: Reg { lam1: 0.0, lam2: 1e-3 },
            has_lazy_skip: true,
        },
        Scenario {
            tag: "squared_hinge+elasticnet",
            ds: synth::tiny(902).generate(),
            loss: SmoothLoss::SquaredHinge,
            reg_kind: RegKind::ElasticNet,
            reg: Reg { lam1: 1e-4, lam2: 1e-4 },
            has_lazy_skip: true,
        },
        Scenario {
            tag: "logistic+group",
            ds: synth::tiny(903).generate(),
            loss: SmoothLoss::Logistic,
            reg_kind: RegKind::GroupLasso { group: 5 },
            reg: Reg { lam1: 0.0, lam2: 1e-3 },
            has_lazy_skip: false,
        },
        Scenario {
            tag: "squared+nonneg",
            ds: synth::tiny(904).with_task(synth::Task::Regression).generate(),
            loss: SmoothLoss::Squared,
            reg_kind: RegKind::NonnegL1,
            reg: Reg { lam1: 0.0, lam2: 1e-3 },
            has_lazy_skip: false,
        },
    ]
}

fn cfg_for(s: &Scenario, backend: WorkerBackend, epochs: usize) -> PscopeConfig {
    PscopeConfig {
        p: 2,
        outer_iters: epochs,
        reg: s.reg,
        loss: Some(s.loss),
        reg_kind: Some(s.reg_kind),
        seed: 11,
        backend,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    }
}

#[test]
fn every_new_pair_decreases_and_converges_on_both_engines() {
    for s in scenarios() {
        let prox = cfg_for(&s, WorkerBackend::RustSparse, 1).prox_reg().unwrap();
        let obj = Objective::new(&s.ds, s.loss, prox);
        let p_ref = reference_optimum(&obj, 20_000).objective;
        for backend in [WorkerBackend::RustSparse, WorkerBackend::RustDense] {
            let cfg = cfg_for(&s, backend, 60);
            let part = Partitioner::Uniform.split(&s.ds, cfg.p, 3);
            let out = train_with(&s.ds, &part, &cfg, None, NetModel::zero()).unwrap();
            let p0 = out.trace.points.first().unwrap().objective;
            let p_last = out.trace.last_objective();
            assert!(
                p_last < p0,
                "{} [{backend:?}]: objective went {p0} -> {p_last}",
                s.tag
            );
            let gap0 = p0 - p_ref;
            let gap = p_last - p_ref;
            // the FISTA reference is tight to ~1e-10 on these tiny
            // problems; a small slack covers losses where it converges
            // sublinearly (no strong convexity)
            assert!(gap > -1e-6, "{} [{backend:?}]: beat the reference by {gap}", s.tag);
            assert!(
                gap < 0.5 * gap0,
                "{} [{backend:?}]: gap {gap} did not close half of initial {gap0}",
                s.tag
            );
        }
    }
}

#[test]
fn lazy_and_dense_agree_where_both_apply() {
    // one inner epoch, engine-level: the lazy recovery rules must match
    // the dense reference to 1e-9 for the new losses too
    for s in scenarios().into_iter().filter(|s| s.has_lazy_skip) {
        let prox = cfg_for(&s, WorkerBackend::RustSparse, 1).prox_reg().unwrap();
        let obj = Objective::new(&s.ds, s.loss, prox);
        let w = vec![0.02; s.ds.d()];
        let z = obj.data_grad(&w);
        let eta = 0.3 / obj.smoothness();
        let m = 2 * s.ds.n();
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let ud = pscope::optim::svrg::dense_inner_epoch(&s.ds, s.loss, &w, &z, eta, prox, m, &mut r1);
        let ul = pscope::optim::lazy::lazy_inner_epoch(
            &s.ds, s.loss, &w, &z, eta, prox, m, &mut r2, &mut Default::default(),
        );
        for j in 0..s.ds.d() {
            assert!(
                (ud[j] - ul[j]).abs() < 1e-9 * (1.0 + ud[j].abs()),
                "{} coord {j}: dense {} vs lazy {}",
                s.tag,
                ud[j],
                ul[j]
            );
        }
    }
}

#[test]
fn sparse_backend_fallback_is_bit_identical_to_dense_backend() {
    // no closed-form skip -> the sparse backend must take the dense
    // engine path: identical bits, and zero lazy materializations
    for s in scenarios().into_iter().filter(|s| !s.has_lazy_skip) {
        let part = Partitioner::Uniform.split(&s.ds, 2, 3);
        let sparse_cfg = cfg_for(&s, WorkerBackend::RustSparse, 6);
        let dense_cfg = cfg_for(&s, WorkerBackend::RustDense, 6);
        let a = train_with(&s.ds, &part, &sparse_cfg, None, NetModel::zero()).unwrap();
        let b = train_with(&s.ds, &part, &dense_cfg, None, NetModel::zero()).unwrap();
        assert_eq!(a.w, b.w, "{}: fallback diverged from the dense backend", s.tag);
        assert_eq!(
            a.materializations, 0,
            "{}: fallback still ran the lazy engine",
            s.tag
        );
    }
    // and regularizers with the skip do run lazily on the sparse backend
    for s in scenarios().into_iter().filter(|s| s.has_lazy_skip).take(1) {
        let part = Partitioner::Uniform.split(&s.ds, 2, 3);
        let cfg = cfg_for(&s, WorkerBackend::RustSparse, 2);
        let out = train_with(&s.ds, &part, &cfg, None, NetModel::zero()).unwrap();
        assert!(out.materializations > 0, "{}: lazy engine never engaged", s.tag);
    }
}

#[test]
fn runspec_ships_objective_bits_end_to_end_over_tcp() {
    // a non-default composite objective — Huber with an inexact-in-binary
    // delta, group-lasso regularizer, sparse backend falling back to the
    // dense engine — through the real wire: the TCP cluster must
    // reproduce the in-process trajectory bit for bit
    let (data_seed, part_seed, p, epochs) = (21u64, 1u64, 2usize, 3usize);
    let ds = synth::tiny(data_seed).generate();
    let cfg = PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 0.0, lam2: 1e-3 },
        loss: Some(SmoothLoss::Huber { delta: 0.3 }),
        reg_kind: Some(RegKind::GroupLasso { group: 5 }),
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let inproc = train_with(&ds, &part, &cfg, None, NetModel::ten_gbe()).unwrap();

    let src = DataSource::Synth { name: "tiny".into(), seed: data_seed };
    let spec = RunSpec::derive(&ds, &part, &cfg, &src, "uniform", part_seed, None).unwrap();
    assert_eq!(spec.loss, SmoothLoss::Huber { delta: 0.3 });
    assert_eq!(spec.reg, ProxReg::GroupLasso { lam: 1e-3, group: 5 });
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..p)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || serve_worker(&addr, Duration::from_secs(30)))
        })
        .collect();
    let tcp = ep
        .train(&ds, &part, &cfg, NetModel::ten_gbe(), &spec, Duration::from_secs(30))
        .unwrap();
    for h in workers {
        h.join().unwrap().unwrap();
    }

    for j in 0..inproc.w.len() {
        assert_eq!(
            inproc.w[j].to_bits(),
            tcp.w[j].to_bits(),
            "coord {j}: inproc {} vs tcp {}",
            inproc.w[j],
            tcp.w[j]
        );
    }
    assert_eq!(inproc.comm, tcp.comm, "byte-meter totals differ across transports");
    for (a, b) in inproc.trace.points.iter().zip(&tcp.trace.points) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "epoch {}", a.epoch);
    }
}

#[test]
fn xla_backend_rejects_non_soft_threshold_regularizers_at_resolve_time() {
    // fail-fast contract: the rejection is a caller-thread config error
    // during resolution, not p worker deaths at the first inner epoch
    let scens = scenarios();
    let s = &scens[2]; // logistic+group
    let cfg = cfg_for(s, WorkerBackend::Xla, 2);
    let part = Partitioner::Uniform.split(&s.ds, cfg.p, 3);
    let err = train_with(&s.ds, &part, &cfg, Some("artifacts".into()), NetModel::zero())
        .unwrap_err();
    assert!(
        format!("{err}").contains("soft-threshold"),
        "unexpected error: {err}"
    );
}

#[test]
fn mismatched_spec_objective_is_rejected_before_training() {
    // MasterEndpoint::train cross-checks the spec's objective bits
    // against its own config resolution — a one-ulp lambda drift fails
    let ds = synth::tiny(31).generate();
    let cfg = PscopeConfig {
        p: 1,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 1, 1);
    let src = DataSource::Synth { name: "tiny".into(), seed: 31 };
    let mut spec = RunSpec::derive(&ds, &part, &cfg, &src, "uniform", 1, None).unwrap();
    spec.reg = ProxReg::ElasticNet {
        lam1: f64::from_bits(1e-3f64.to_bits() ^ 1),
        lam2: 1e-3,
    };
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let err = ep
        .train(&ds, &part, &cfg, NetModel::zero(), &spec, Duration::from_secs(5))
        .unwrap_err();
    assert!(
        format!("{err}").contains("objective"),
        "unexpected error: {err}"
    );
}
