//! Elastic-cluster contracts (DESIGN.md §11), over real loopback TCP:
//!
//! * elastic mode with every worker alive is bit-identical to strict mode
//!   (and therefore to the in-process wire) — iterates, objectives, and
//!   byte totals, which also pins that heartbeats are unmetered;
//! * losing a worker mid-run degrades the run instead of aborting it, and
//!   the degradation event carries the Lemma-5 γ proxy of the surviving
//!   sub-partition;
//! * resume-from-checkpoint is deterministic: two fresh clusters resumed
//!   from the same checkpoint produce bit-identical trajectories;
//! * strict mode on the same fault fails fast with `Error::Protocol`
//!   naming the peer's socket address;
//! * the worker connect retry uses bounded exponential backoff and
//!   reports its attempts on exhaustion.

use std::time::{Duration, Instant};

use pscope::config::{Model, PscopeConfig, RunMode};
use pscope::coordinator::checkpoint::{self, Checkpoint};
use pscope::coordinator::elastic::ElasticOpts;
use pscope::coordinator::remote::{serve_worker, MasterEndpoint, RunSpec, WorkerOpts};
use pscope::coordinator::{train_with, TrainOutput};
use pscope::data::source::DataSource;
use pscope::data::synth;
use pscope::error::Result;
use pscope::loss::Reg;
use pscope::net::transport::FaultPlan;
use pscope::net::NetModel;
use pscope::partition::Partitioner;

fn base_cfg(p: usize, epochs: usize) -> PscopeConfig {
    PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    }
}

fn elastic_cfg(p: usize, epochs: usize) -> PscopeConfig {
    PscopeConfig {
        mode: RunMode::Elastic,
        heartbeat_ms: 25,
        ..base_cfg(p, epochs)
    }
}

/// Spin up a loopback cluster — master endpoint + one genuine worker
/// client thread per entry of `faults` — and train in elastic mode.
/// Returns the master's outcome plus every worker thread's result (a
/// killed worker is *supposed* to come back `Err`).
fn elastic_train(
    ds: &pscope::data::Dataset,
    part: &pscope::partition::Partition,
    cfg: &PscopeConfig,
    data_seed: u64,
    part_seed: u64,
    faults: &[&str],
    resume: Option<&Checkpoint>,
) -> (Result<TrainOutput>, Vec<Result<()>>) {
    assert_eq!(faults.len(), part.p());
    let src = DataSource::Synth { name: "tiny".into(), seed: data_seed };
    let spec = RunSpec::derive(ds, part, cfg, &src, "uniform", part_seed, None).unwrap();
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap().to_string();
    let handles: Vec<_> = faults
        .iter()
        .map(|f| {
            let addr = addr.clone();
            let opts = WorkerOpts {
                connect_timeout: Duration::from_secs(30),
                timeout: Duration::from_secs(30),
                fault: FaultPlan::parse(f, 0).unwrap(),
            };
            std::thread::spawn(move || pscope::coordinator::remote::serve_worker_with(&addr, &opts))
        })
        .collect();
    let out = ep.train_elastic(
        ds,
        part,
        cfg,
        NetModel::ten_gbe(),
        &spec,
        Duration::from_secs(30),
        &ElasticOpts::from_config(cfg),
        resume,
    );
    let joined = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (out, joined)
}

#[test]
fn elastic_without_faults_is_bit_identical_to_strict() {
    // With every worker alive, the elastic loop must be indistinguishable
    // from strict mode: same fold order, same 1/p average, and unmetered
    // heartbeats — so iterates, objectives, AND byte totals all match the
    // in-process strict run exactly (which tests/net_accounting.rs pins
    // equal to strict TCP).
    let (data_seed, part_seed, p, epochs) = (31u64, 1u64, 3usize, 4usize);
    let ds = synth::tiny(data_seed).generate();
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let strict = train_with(&ds, &part, &base_cfg(p, epochs), None, NetModel::ten_gbe()).unwrap();

    let cfg = elastic_cfg(p, epochs);
    let (out, workers) = elastic_train(&ds, &part, &cfg, data_seed, part_seed,
        &["none", "none", "none"], None);
    let out = out.unwrap();
    for r in workers {
        r.unwrap();
    }

    assert!(out.degraded.is_empty(), "degradation events in a healthy run");
    assert_eq!(out.epochs_run, strict.epochs_run);
    for j in 0..strict.w.len() {
        assert_eq!(
            strict.w[j].to_bits(),
            out.w[j].to_bits(),
            "coord {j}: strict {} vs elastic {}",
            strict.w[j],
            out.w[j]
        );
    }
    // byte-meter identity: if a single heartbeat were metered these totals
    // would disagree (the beacons definitely flowed — 25 ms interval)
    assert_eq!(strict.comm, out.comm, "heartbeats leaked into the byte meter");
    assert_eq!(strict.trace.points.len(), out.trace.points.len());
    for (a, b) in strict.trace.points.iter().zip(&out.trace.points) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "epoch {}", a.epoch);
        assert_eq!((a.comm_bytes, a.comm_msgs), (b.comm_bytes, b.comm_msgs), "epoch {}", a.epoch);
    }
}

#[test]
fn worker_loss_degrades_run_and_reports_gamma() {
    // p = 4, one worker killed at epoch 2: the run must complete all
    // epochs on the 3 survivors, log exactly one degradation event with a
    // finite γ proxy for the surviving sub-partition, and keep writing
    // checkpoints to the end.
    let (data_seed, part_seed, p, epochs) = (32u64, 1u64, 4usize, 6usize);
    let ds = synth::tiny(data_seed).generate();
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let dir = std::env::temp_dir().join(format!("pscope_elastic_kill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = elastic_cfg(p, epochs);
    cfg.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    cfg.checkpoint_every = 1;

    let (out, workers) = elastic_train(&ds, &part, &cfg, data_seed, part_seed,
        &["kill@2", "none", "none", "none"], None);
    let out = out.expect("elastic master must survive one lost worker");

    assert_eq!(out.epochs_run, epochs, "degraded run stopped early");
    assert_eq!(out.degraded.len(), 1, "expected exactly one degradation event");
    let ev = &out.degraded[0];
    assert_eq!(ev.survivors, p - 1);
    assert!(ev.epoch >= 2, "fault fires at epoch 2, event at {}", ev.epoch);
    assert!(
        ev.gamma_surviving.is_finite() && ev.gamma_surviving > 0.0,
        "gamma proxy of the survivors: {}",
        ev.gamma_surviving
    );
    assert!(
        ev.gamma_original.is_finite() && ev.gamma_original > 0.0,
        "gamma proxy of the original partition: {}",
        ev.gamma_original
    );
    // exactly one worker died, and it names the injected fault
    let errs: Vec<String> = workers
        .into_iter()
        .filter_map(|r| r.err().map(|e| format!("{e}")))
        .collect();
    assert_eq!(errs.len(), 1, "exactly one worker should fail: {errs:?}");
    assert!(errs[0].contains("fault injection"), "{}", errs[0]);
    // checkpoints ran to the end despite the degradation
    let last = checkpoint::latest(&dir).unwrap().expect("no checkpoint written");
    let ck = Checkpoint::load(&last).unwrap();
    assert_eq!(ck.epoch, epochs);
    assert_eq!(ck.p, p);
    assert_eq!(ck.part_fingerprint, part.fingerprint());
    assert_eq!(ck.w.len(), ds.d());
    for j in 0..ds.d() {
        assert_eq!(ck.w[j].to_bits(), out.w[j].to_bits(), "checkpoint coord {j}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_from_checkpoint_is_deterministic() {
    // The rejoin contract (restart ≡ restart): a run that lost a worker
    // leaves a checkpoint; two *fresh, full* clusters resumed from that
    // checkpoint must produce bit-identical trajectories, because every
    // worker rebuilds shard + RNG deterministically from the job spec.
    let (data_seed, part_seed, p) = (33u64, 1u64, 2usize);
    let ds = synth::tiny(data_seed).generate();
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let dir = std::env::temp_dir().join(format!("pscope_elastic_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // first run: 4 epochs, loses worker at epoch 2, checkpoints throughout
    let mut cfg = elastic_cfg(p, 4);
    cfg.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    cfg.checkpoint_every = 1;
    let (first, _workers) =
        elastic_train(&ds, &part, &cfg, data_seed, part_seed, &["kill@2", "none"], None);
    let first = first.unwrap();
    assert_eq!(first.degraded.len(), 1);
    let ck = Checkpoint::load(&checkpoint::latest(&dir).unwrap().unwrap()).unwrap();
    assert_eq!(ck.epoch, 4);

    // resume twice with full worker sets, no further checkpoint writes
    let mut cfg2 = elastic_cfg(p, 8);
    cfg2.checkpoint_every = 0;
    let mut resumed = Vec::new();
    for _ in 0..2 {
        let (out, workers) =
            elastic_train(&ds, &part, &cfg2, data_seed, part_seed, &["none", "none"], Some(&ck));
        let out = out.unwrap();
        for r in workers {
            r.unwrap();
        }
        assert!(out.degraded.is_empty());
        assert_eq!(out.epochs_run, 8);
        assert_eq!(out.trace.points.first().unwrap().epoch, 4, "trace must start at the resume");
        resumed.push(out);
    }
    let (a, b) = (&resumed[0], &resumed[1]);
    for j in 0..a.w.len() {
        assert_eq!(a.w[j].to_bits(), b.w[j].to_bits(), "resumed runs diverge at coord {j}");
    }
    assert_eq!(a.comm, b.comm);
    for (x, y) in a.trace.points.iter().zip(&b.trace.points) {
        assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "epoch {}", x.epoch);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_rejects_a_mismatched_checkpoint() {
    // A checkpoint from a different partition must be refused before any
    // epoch runs — silently training from a foreign iterate would corrupt
    // the trajectory invisibly.
    let (data_seed, part_seed, p) = (35u64, 1u64, 2usize);
    let ds = synth::tiny(data_seed).generate();
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let ck = Checkpoint {
        epoch: 1,
        p,
        seed: 5,
        part_fingerprint: part.fingerprint() ^ 1,
        w: vec![0.0; ds.d()],
    };
    let cfg = elastic_cfg(p, 3);
    let (out, workers) =
        elastic_train(&ds, &part, &cfg, data_seed, part_seed, &["none", "none"], Some(&ck));
    let err = out.expect_err("mismatched checkpoint accepted");
    assert!(format!("{err}").contains("fingerprint"), "{err}");
    // the cluster tears down cleanly: workers drain on Stop, not errors
    for r in workers {
        r.unwrap();
    }
}

#[test]
fn strict_mode_fails_fast_and_names_the_peer() {
    // The same kill fault under strict mode: the master must abort with
    // Error::Protocol quickly, and the message must carry the worker's
    // socket address (the elastic PR's observability satellite).
    let (data_seed, part_seed, p) = (34u64, 1u64, 2usize);
    let ds = synth::tiny(data_seed).generate();
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let cfg = base_cfg(p, 10);
    assert_eq!(cfg.mode, RunMode::Strict);
    let src = DataSource::Synth { name: "tiny".into(), seed: data_seed };
    let spec = RunSpec::derive(&ds, &part, &cfg, &src, "uniform", part_seed, None).unwrap();
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap().to_string();
    let handles: Vec<_> = ["kill@1", "none"]
        .iter()
        .map(|f| {
            let addr = addr.clone();
            let opts = WorkerOpts {
                connect_timeout: Duration::from_secs(30),
                timeout: Duration::from_secs(30),
                fault: FaultPlan::parse(f, 0).unwrap(),
            };
            std::thread::spawn(move || pscope::coordinator::remote::serve_worker_with(&addr, &opts))
        })
        .collect();
    let start = Instant::now();
    let err = ep
        .train(&ds, &part, &cfg, NetModel::zero(), &spec, Duration::from_secs(30))
        .expect_err("strict mode must abort on a killed worker");
    assert!(start.elapsed() < Duration::from_secs(30), "abort took {:?}", start.elapsed());
    let msg = format!("{err}");
    assert!(msg.contains("died"), "unexpected message: {msg}");
    assert!(msg.contains("127.0.0.1"), "peer address missing from: {msg}");
    // one worker reports the injected fault; the survivor drains cleanly
    let results: Vec<Result<()>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let n_err = results.iter().filter(|r| r.is_err()).count();
    assert_eq!(n_err, 1, "{results:?}");
}

#[test]
fn connect_retry_reports_attempts_and_deadline() {
    // Grab an ephemeral port, then close the listener: connecting there
    // must retry with backoff until the deadline and then report how hard
    // it tried.
    let dead_addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let start = Instant::now();
    let err = serve_worker(&dead_addr, Duration::from_millis(400))
        .expect_err("connected to a closed port?");
    let elapsed = start.elapsed();
    assert!(elapsed >= Duration::from_millis(350), "gave up too early: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(10), "retried past the deadline: {elapsed:?}");
    let msg = format!("{err}");
    assert!(msg.contains("cannot connect"), "{msg}");
    assert!(msg.contains("attempts"), "exhaustion must report retry attempts: {msg}");
}
