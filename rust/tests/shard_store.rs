//! Tier-1 guarantees for the out-of-core data layer (`data::shard` +
//! `data::source`, DESIGN.md §10):
//!
//! 1. LibSVM text round-trips **bit-for-bit** through `write` → `read`
//!    (property-tested: NaN payloads, empty rows, trailing empty columns
//!    under `d_hint`) — the precondition for `pscope ingest` reproducing
//!    an in-memory run from a text file;
//! 2. a full `ingest → load_dir → TCP train` run from a shard directory
//!    is **bit-identical** to the in-memory InProc run on the same text:
//!    final iterate, per-epoch objectives, meter totals, epochs,
//!    materializations;
//! 3. each worker materializes *only its own shard*, proven by the
//!    chunked reader's row accounting — never the full dataset;
//! 4. corrupt shard files (truncation, a single flipped payload byte)
//!    are loud `Error::Protocol` failures at worker build time, before
//!    any training step consumes a poisoned row.

use std::path::PathBuf;
use std::time::Duration;

use pscope::config::{Model, PscopeConfig};
use pscope::coordinator::remote::{build_worker, serve_worker, MasterEndpoint, RunSpec};
use pscope::coordinator::train_with;
use pscope::data::source::DataSource;
use pscope::data::{libsvm, shard, synth, Dataset};
use pscope::error::Error;
use pscope::linalg::CsrMatrix;
use pscope::loss::Reg;
use pscope::net::NetModel;
use pscope::partition::Partitioner;
use pscope::testkit::prop;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pscope_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn write_libsvm(ds: &Dataset, path: &std::path::Path) {
    let f = std::fs::File::create(path).unwrap();
    libsvm::write(ds, std::io::BufWriter::new(f)).unwrap();
}

fn assert_datasets_bit_equal(a: &Dataset, b: &Dataset, what: &str) {
    assert_eq!(a.n(), b.n(), "{what}: n");
    assert_eq!(a.x.ncols, b.x.ncols, "{what}: d");
    assert_eq!(a.x.indptr, b.x.indptr, "{what}: indptr");
    assert_eq!(a.x.indices, b.x.indices, "{what}: indices");
    for i in 0..a.n() {
        assert_eq!(a.y[i].to_bits(), b.y[i].to_bits(), "{what}: label {i}");
    }
    for (j, (u, v)) in a.x.values.iter().zip(&b.x.values).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}: value {j}");
    }
}

// ---- 1. LibSVM text round-trip (property) -------------------------------

#[test]
fn libsvm_write_read_roundtrips_bit_for_bit() {
    prop::check("libsvm write→read roundtrips bit-for-bit", 60, |rng, shrink| {
        let cap = if shrink > 0 { 4 } else { 30 };
        let n = 1 + rng.below(cap);
        let d = 1 + rng.below(cap + 10);
        let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let mut row: Vec<(u32, f64)> = Vec::new();
            // ~20% empty rows (a legal LibSVM line: label only)
            if !rng.bool(0.2) {
                let nnz = 1 + rng.below(d);
                let mut cols = rng.sample_distinct(d, nnz);
                cols.sort_unstable();
                for j in cols {
                    // NaN payloads and wide magnitudes must survive the
                    // text trip (Display is shortest-roundtrip in Rust)
                    let v = if rng.bool(0.05) {
                        f64::NAN
                    } else {
                        let m = rng.normal() * 10f64.powi(rng.below(9) as i32 - 4);
                        if m == 0.0 { 1.0 } else { m }
                    };
                    row.push((j as u32, v));
                }
            }
            rows.push(row);
            // labels: mostly ±1, sometimes arbitrary reals (regression)
            y.push(if rng.bool(0.8) {
                if rng.bool(0.5) { 1.0 } else { -1.0 }
            } else {
                rng.normal()
            });
        }
        let ds = Dataset { name: "prop".into(), x: CsrMatrix::from_rows(d, &rows), y };
        let mut buf = Vec::new();
        libsvm::write(&ds, &mut buf).unwrap();
        // d_hint = d: trailing all-zero columns are invisible in the text
        let back = libsvm::read(std::io::BufReader::new(&buf[..]), "prop", d).unwrap();
        let ok = back.x.ncols == ds.x.ncols
            && back.x.indptr == ds.x.indptr
            && back.x.indices == ds.x.indices
            && back.y.len() == ds.y.len()
            && ds.y.iter().zip(&back.y).all(|(a, b)| a.to_bits() == b.to_bits())
            && ds.x.values.iter().zip(&back.x.values).all(|(a, b)| a.to_bits() == b.to_bits());
        prop::that(ok, format!("n={n} d={d} nnz={}", ds.nnz()))
    });
}

// ---- 2 + 3. shard-dir run pinned bit-identical; per-shard residency -----

#[test]
fn sharddir_tcp_run_is_bit_identical_to_in_memory_inproc_run() {
    let dir = tmpdir("pin");
    let input = dir.join("tiny_skew.libsvm");
    write_libsvm(&synth::tiny_skew(33).generate(), &input);

    let (p, part_seed, epochs) = (3usize, 9u64, 4usize);
    let shards = dir.join("shards");
    let report =
        shard::ingest(&input, &shards, "skew75", p, part_seed, "tiny_skew", 0).unwrap();
    let manifest = report.manifest;

    // in-memory reference: parse the same text, split the same way
    let ds_mem = libsvm::read_file(&input, 0).unwrap();
    assert_eq!(manifest.n as usize, ds_mem.n());
    let part_mem = Partitioner::LabelSkew75.split(&ds_mem, p, part_seed);
    let cfg = PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny_skew", Model::Logistic)
    };
    let inproc = train_with(&ds_mem, &part_mem, &cfg, None, NetModel::ten_gbe()).unwrap();

    // master side of a shard-dir run: dataset + partition reconstructed
    // from the binary store, in original row order
    let (ds_sh, part_sh, manifest2) = shard::load_dir(&shards).unwrap();
    assert_eq!(manifest2.part_fingerprint, manifest.part_fingerprint);
    assert_eq!(
        part_sh.assignment, part_mem.assignment,
        "ingest-time split differs from the in-memory split"
    );
    assert_datasets_bit_equal(&ds_sh, &ds_mem, "load_dir vs libsvm::read_file");

    // the spec's digest table must equal the shard files' digests: what
    // the master derives from memory is what the files carry
    let src = DataSource::ShardDir { dir: shards.to_string_lossy().into_owned() };
    let spec = RunSpec::derive(
        &ds_sh,
        &part_sh,
        &cfg,
        &src,
        &manifest.partition,
        manifest.part_seed,
        None,
    )
    .unwrap();
    let file_digests: Vec<u64> = manifest.shards.iter().map(|s| s.digest).collect();
    assert_eq!(spec.shard_digests, file_digests, "derive vs ingest digest table");

    // every worker materializes its own shard only — row accounting from
    // the chunked reader, summing back to n across the cluster
    let mut rows_total = 0usize;
    for k in 0..p {
        let (_, _, stats) = shard::load_worker_shard(&shards, k, &manifest).unwrap();
        assert_eq!(stats.rows_read as u64, manifest.shards[k].rows, "worker {k} rows");
        assert!(
            (stats.rows_read as u64) < manifest.n,
            "worker {k} materialized the full dataset ({} rows)",
            stats.rows_read
        );
        assert!(stats.peak_chunk_rows <= shard::DEFAULT_CHUNK_ROWS);
        rows_total += stats.rows_read;
    }
    assert_eq!(rows_total as u64, manifest.n, "shards must cover the dataset");

    // the real thing: a loopback TCP cluster trained from the shard dir
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..p)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || serve_worker(&addr, Duration::from_secs(30)))
        })
        .collect();
    let tcp = ep
        .train(&ds_sh, &part_sh, &cfg, NetModel::ten_gbe(), &spec, Duration::from_secs(30))
        .unwrap();
    for h in workers {
        h.join().unwrap().unwrap();
    }

    assert_eq!(inproc.w.len(), tcp.w.len());
    for j in 0..inproc.w.len() {
        assert_eq!(
            inproc.w[j].to_bits(),
            tcp.w[j].to_bits(),
            "coord {j}: inproc {} vs shard-dir tcp {}",
            inproc.w[j],
            tcp.w[j]
        );
    }
    assert_eq!(inproc.epochs_run, tcp.epochs_run);
    assert_eq!(inproc.materializations, tcp.materializations);
    assert_eq!(inproc.comm, tcp.comm, "byte-meter totals differ");
    assert_eq!(inproc.trace.points.len(), tcp.trace.points.len());
    for (a, b) in inproc.trace.points.iter().zip(&tcp.trace.points) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "epoch {}", a.epoch);
    }

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- 4. corruption fails loudly before training -------------------------

#[test]
fn corrupt_shards_are_protocol_errors_before_training() {
    let dir = tmpdir("corrupt");
    let input = dir.join("tiny.libsvm");
    write_libsvm(&synth::tiny(15).generate(), &input);
    let shards = dir.join("shards");
    shard::ingest(&input, &shards, "uniform", 2, 3, "tiny", 0).unwrap();

    let (ds, part, manifest) = shard::load_dir(&shards).unwrap();
    let cfg = PscopeConfig { p: 2, ..PscopeConfig::for_dataset("tiny", Model::Logistic) };
    let src = DataSource::ShardDir { dir: shards.to_string_lossy().into_owned() };
    let spec = RunSpec::derive(
        &ds,
        &part,
        &cfg,
        &src,
        &manifest.partition,
        manifest.part_seed,
        None,
    )
    .unwrap();

    let path = shard::shard_path(&shards, 1);
    let pristine = std::fs::read(&path).unwrap();

    // pristine bytes build fine — the baseline for the corruptions below
    build_worker(&spec, 1).unwrap();

    // truncation: the tail of the payload vanishes
    std::fs::write(&path, &pristine[..pristine.len() - 7]).unwrap();
    let err = build_worker(&spec, 1).unwrap_err();
    assert!(matches!(err, Error::Protocol(_)), "truncation surfaced as {err:?}");

    // a single flipped payload byte: caught by the FNV digest
    let mut flipped = pristine.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0x04;
    std::fs::write(&path, &flipped).unwrap();
    let err = build_worker(&spec, 1).unwrap_err();
    assert!(matches!(err, Error::Protocol(_)), "bit flip surfaced as {err:?}");
    assert!(format!("{err}").contains("digest"), "bit flip error names the digest: {err}");

    // restore → loads cleanly again (the checks are about bytes, not state)
    std::fs::write(&path, &pristine).unwrap();
    build_worker(&spec, 1).unwrap();

    std::fs::remove_dir_all(&dir).unwrap();
}
