//! Tier-1 guarantees for the partition-construction engine
//! (`partition::engine`, DESIGN.md §8):
//!
//! 1. an engineered partition is always a disjoint cover with balanced
//!    shard sizes;
//! 2. the search is bit-identical across runs with the same seed (the
//!    `RunSpec` regenerate-on-worker contract) — including through
//!    `coordinator::remote::build_worker`, the path a TCP worker takes;
//! 3. on the label-skewed synthetic (`tiny_skew`, the instance whose
//!    class-conditional curvature makes π₂/π₃ bad), the *measured*
//!    goodness γ̂ of the engineered partition is ≤ the uniform π₁
//!    baseline — the acceptance bar for "construct good partitions,
//!    don't just measure them";
//! 4. the closed-form quadratic proxy the refinement optimizes ranks
//!    partitions the same way the FISTA-measured γ̂ does (rank
//!    agreement on decisively separated pairs).

use pscope::config::{Model, PscopeConfig};
use pscope::coordinator::remote::{build_worker, RunSpec};
use pscope::data::source::DataSource;
use pscope::data::synth;
use pscope::partition::engine::{self, EngineOpts};
use pscope::partition::goodness::{analyze, GoodnessOpts};
use pscope::partition::Partitioner;

const SEED: u64 = 42;

fn gopts() -> GoodnessOpts {
    GoodnessOpts {
        local_iters: 2500,
        ref_iters: 12_000,
        ..GoodnessOpts::quick()
    }
}

#[test]
fn engineered_is_disjoint_cover_across_shapes() {
    for (n, p, seed) in [(200, 8, 1u64), (173, 6, 2), (64, 64, 3), (500, 3, 4)] {
        let ds = synth::tiny_skew(seed).with_n(n).generate();
        let part = Partitioner::Engineered.split(&ds, p, seed);
        assert!(part.is_disjoint_cover(n), "n={n} p={p} seed={seed}");
        let sizes: Vec<usize> = part.assignment.iter().map(|a| a.len()).collect();
        let (mn, mx) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "n={n} p={p}: unbalanced sizes {sizes:?}");
    }
}

#[test]
fn engineered_bit_identical_across_runs_and_through_run_spec() {
    let ds = synth::tiny_skew(SEED).generate();
    let a = Partitioner::Engineered.split(&ds, 4, SEED);
    let b = Partitioner::Engineered.split(&ds, 4, SEED);
    assert_eq!(a.assignment, b.assignment, "same seed must reproduce the search");
    assert_eq!(a.fingerprint(), b.fingerprint());
    let c = Partitioner::Engineered.split(&ds, 4, SEED + 1);
    assert_ne!(a.assignment, c.assignment, "seed must matter");

    // the remote-worker path: spec → regenerate dataset → replay search →
    // fingerprint-validated shard, equal to the master-side select
    let cfg = PscopeConfig { p: 4, ..PscopeConfig::for_dataset("tiny_skew", Model::Logistic) };
    let src = DataSource::Synth { name: "tiny_skew".into(), seed: SEED };
    let spec = RunSpec::derive(&ds, &a, &cfg, &src, "engineered", SEED, None).unwrap();
    assert_eq!(spec.part_fingerprint, a.fingerprint());
    for k in 0..4 {
        let wk = build_worker(&spec, k).unwrap();
        let expect = ds.select(&a.assignment[k]);
        assert_eq!(wk.shard.y, expect.y, "worker {k} labels");
        assert_eq!(wk.shard.x.values, expect.x.values, "worker {k} values");
        assert_eq!(wk.shard.x.indices, expect.x.indices, "worker {k} indices");
    }
}

#[test]
fn engineered_gamma_at_most_uniform_on_skewed_synthetic() {
    let ds = synth::tiny_skew(SEED).generate();
    let (loss, reg) = (Model::Logistic.loss(), pscope::loss::Reg { lam1: 1e-2, lam2: 1e-3 });
    let o = gopts();
    let uni = analyze(&ds, &Partitioner::Uniform.split(&ds, 8, SEED), loss, reg, &o);
    let eng = analyze(&ds, &Partitioner::Engineered.split(&ds, 8, SEED), loss, reg, &o);
    assert!(
        eng.gamma_hat <= uni.gamma_hat,
        "engineered γ̂ {} above uniform baseline {}",
        eng.gamma_hat,
        uni.gamma_hat
    );
    // and the engineered partition is still a legal training input
    assert!(eng.gap_at_optimum.abs() < 1e-5, "gap@opt {}", eng.gap_at_optimum);
}

#[test]
fn proxy_ranks_like_measured_gamma() {
    let ds = synth::tiny_skew(SEED).generate();
    let (loss, reg) = (Model::Logistic.loss(), pscope::loss::Reg { lam1: 1e-2, lam2: 1e-3 });
    let (o, eopts) = (gopts(), EngineOpts::default());
    let mut tags = Vec::new();
    let mut proxy = Vec::new();
    let mut measured = Vec::new();
    for strat in Partitioner::all_with_engineered() {
        let part = strat.split(&ds, 8, SEED);
        tags.push(part.tag.clone());
        proxy.push(engine::proxy_gamma(&ds, &part, &eopts));
        measured.push(analyze(&ds, &part, loss, reg, &o).gamma_hat);
    }
    // every decisively separated pair (measured γ̂ apart by ≥ 2x) must be
    // ordered the same way by the closed-form proxy
    let mut checked = 0;
    for i in 0..tags.len() {
        for j in 0..tags.len() {
            if measured[i].max(1e-12) * 2.0 <= measured[j] {
                checked += 1;
                assert!(
                    proxy[i] < proxy[j],
                    "measured γ̂ orders {} ({:.3e}) << {} ({:.3e}) but proxy disagrees \
                     ({:.3e} vs {:.3e})",
                    tags[i],
                    measured[i],
                    tags[j],
                    measured[j],
                    proxy[i],
                    proxy[j]
                );
            }
        }
    }
    // the skewed instance must actually separate the strategies — π₃ vs
    // π* at minimum — or this test would be vacuous
    assert!(checked >= 2, "only {checked} decisively separated pairs");
}
