//! Corollary 2 (E7): with p = 1, pSCOPE degenerates to serial proximal
//! SVRG — trajectory-exact, and converging at the serial rate.

use pscope::config::{Model, PscopeConfig};
use pscope::coordinator::train_with;
use pscope::data::synth;
use pscope::loss::{Objective, Reg};
use pscope::net::NetModel;
use pscope::optim::fista::reference_optimum;
use pscope::optim::lazy::{lazy_inner_epoch, LazyStats};
use pscope::partition::Partitioner;
use pscope::rng::Rng;

#[test]
fn p1_trajectory_is_serial_prox_svrg() {
    let ds = synth::tiny(44).with_n(300).generate();
    let reg = Reg { lam1: 2e-3, lam2: 1e-3 };
    let (m, eta, epochs) = (600usize, 0.08, 5usize);
    let cfg = PscopeConfig {
        p: 1,
        outer_iters: epochs,
        m_inner: m,
        eta,
        reg,
        seed: 99,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 1, 0);
    let out = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap();

    // serial prox-SVRG with the coordinator's per-worker rng stream
    let obj = Objective::new(&ds, Model::Logistic.loss(), reg);
    let mut w = vec![0.0; ds.d()];
    let mut rng = Rng::new(99).fork(1);
    let mut stats = LazyStats::default();
    for _ in 0..epochs {
        let z = obj.data_grad(&w);
        w = lazy_inner_epoch(
            &ds,
            Model::Logistic.loss(),
            &w,
            &z,
            eta,
            reg,
            m,
            &mut rng,
            &mut stats,
        );
    }
    assert_eq!(out.w, w, "p=1 coordinator deviated from serial prox-SVRG");
}

#[test]
fn p1_converges_at_serial_rate() {
    let ds = synth::tiny(45).with_n(300).generate();
    let reg = Reg { lam1: 1e-3, lam2: 1e-3 };
    let obj = Objective::new(&ds, Model::Logistic.loss(), reg);
    let opt = reference_optimum(&obj, 20_000);
    let cfg = PscopeConfig {
        p: 1,
        outer_iters: 30,
        reg,
        seed: 7,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 1, 0);
    let out = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap();
    let gap = out.trace.last_objective() - opt.objective;
    assert!(gap < 1e-8, "serial rate not reached: gap {gap}");
    // linear-rate check: log-gap decreases roughly linearly over epochs
    let gaps: Vec<f64> = out
        .trace
        .points
        .iter()
        .map(|p| (p.objective - opt.objective).max(1e-16))
        .collect();
    let early = (gaps[2] / gaps[0]).ln();
    let late = (gaps[12] / gaps[10]).ln();
    assert!(early < 0.0 && late < 0.0, "no contraction: early {early} late {late}");
}
