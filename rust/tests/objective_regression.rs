//! Bit-identical-trajectory regression for the composite-objective
//! refactor: existing `logistic` and `lasso` configs must produce exactly
//! the trajectories they produced before the `SmoothLoss`/`ProxReg` layer
//! existed (PR 4 HEAD).
//!
//! Golden bits can't be stored here (they'd be toolchain-independent only
//! by luck), so the pin is a *transcription*: `legacy_dense_epoch` below
//! is a line-for-line port of the pre-refactor dense engine — hardcoded
//! soft threshold, `(1 − ηλ₁)` decay, `thr = ηλ₂`, identical op order —
//! and `legacy_call_round` replays the pre-refactor master fold (reduce
//! in worker order, scale once). The refactored stack must match both
//! **bit for bit**:
//!
//! 1. engine level — the new `dense_inner_epoch` (ProxReg-dispatched)
//!    against the transcription, logistic and lasso;
//! 2. coordinator level — a full `train_with` run (p = 2, dense backend)
//!    against a serial replay of Algorithm 1 built only from the
//!    transcription + the master's documented reduce order;
//! 3. config level — the legacy Model-preset config path against an
//!    explicit `loss`/`reg` override naming the same objective.
//!
//! The lazy engine is pinned to the dense engine elsewhere
//! (`tests/lazy_equivalence.rs`), which closes the loop for the sparse
//! backend.

// the transcriptions mirror the pre-refactor signatures, scalars and all
#![allow(clippy::too_many_arguments)]

use pscope::config::{Model, PscopeConfig, RegKind, WorkerBackend};
use pscope::coordinator::train_with;
use pscope::data::{synth, Dataset};
use pscope::loss::{Loss, Objective, Reg, SmoothLoss};
use pscope::net::NetModel;
use pscope::partition::Partitioner;
use pscope::rng::Rng;

/// Pre-refactor soft threshold (transcribed).
fn legacy_soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

/// Line-for-line port of the pre-refactor dense inner epoch: decay and
/// threshold precomputed, fused per-coordinate update, one `below(n)` per
/// step.
fn legacy_dense_epoch(
    shard: &Dataset,
    loss: Loss,
    w_t: &[f64],
    z: &[f64],
    eta: f64,
    lam1: f64,
    lam2: f64,
    m_steps: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let d = shard.d();
    let n = shard.n();
    let decay = 1.0 - eta * lam1;
    let thr = eta * lam2;
    let mut u = w_t.to_vec();
    let cw: Vec<f64> = (0..n)
        .map(|i| loss.hprime(shard.x.row(i).dot(w_t), shard.y[i]))
        .collect();
    for _ in 0..m_steps {
        let i = rng.below(n);
        let row = shard.x.row(i);
        let coeff = loss.hprime(row.dot(&u), shard.y[i]) - cw[i];
        let mut k = 0usize;
        for j in 0..d {
            let mut g = z[j];
            if k < row.idx.len() && row.idx[k] as usize == j {
                g += coeff * row.val[k];
                k += 1;
            }
            u[j] = legacy_soft_threshold(decay * u[j] - eta * g, thr);
        }
    }
    u
}

fn problems() -> Vec<(Dataset, Loss, Reg, &'static str)> {
    vec![
        (
            synth::tiny(1201).generate(),
            SmoothLoss::Logistic,
            Reg { lam1: 1e-3, lam2: 1e-3 },
            "logistic",
        ),
        (
            synth::tiny(1202)
                .with_task(synth::Task::Regression)
                .generate(),
            SmoothLoss::Squared,
            Reg { lam1: 0.0, lam2: 5e-3 }, // the Lasso corner: no ridge
            "lasso",
        ),
    ]
}

#[test]
fn dense_engine_is_bit_identical_to_legacy_transcription() {
    for (ds, loss, reg, tag) in problems() {
        let obj = Objective::new(&ds, loss, reg);
        let w = vec![0.02; ds.d()];
        let z = obj.data_grad(&w);
        let eta = 0.3 / obj.smoothness();
        let m = 2 * ds.n();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let legacy = legacy_dense_epoch(&ds, loss, &w, &z, eta, reg.lam1, reg.lam2, m, &mut r1);
        let new = pscope::optim::svrg::dense_inner_epoch(&ds, loss, &w, &z, eta, reg, m, &mut r2);
        for j in 0..ds.d() {
            assert_eq!(
                legacy[j].to_bits(),
                new[j].to_bits(),
                "{tag} coord {j}: legacy {} vs refactored {}",
                legacy[j],
                new[j]
            );
        }
    }
}

/// Serial replay of Algorithm 1 exactly as the pre-refactor coordinator
/// executed it for the dense backend: per epoch, (a) every worker's raw
/// shard-gradient sum (single reduction block at these shard sizes — the
/// plain row-order accumulation), (b) the master's worker-order fold and
/// single 1/n scale, (c) every worker's dense epoch on its forked RNG
/// stream, (d) the master's worker-order iterate fold and 1/p scale.
fn legacy_call_trajectory(
    ds: &Dataset,
    part: &[Vec<usize>],
    loss: Loss,
    reg: Reg,
    eta: f64,
    m_inner: usize,
    seed: u64,
    epochs: usize,
) -> Vec<f64> {
    let p = part.len();
    let d = ds.d();
    let shards: Vec<Dataset> = part.iter().map(|rows| ds.select(rows)).collect();
    let root = Rng::new(seed);
    let mut rngs: Vec<Rng> = (0..p).map(|k| root.fork(k as u64 + 1)).collect();
    let mut w = vec![0.0; d];
    for _ in 0..epochs {
        // (a) + (b): z = (sum_k zsum_k) / n, folded in worker order
        let mut z = vec![0.0; d];
        let mut total = 0usize;
        for shard in &shards {
            let mut zsum = vec![0.0; d];
            for i in 0..shard.n() {
                let row = shard.x.row(i);
                let c = loss.hprime(row.dot(&w), shard.y[i]);
                row.axpy_into(c, &mut zsum);
            }
            for j in 0..d {
                z[j] += zsum[j];
            }
            total += shard.n();
        }
        for v in z.iter_mut() {
            *v *= 1.0 / total as f64;
        }
        // (c) + (d): u_mean = (sum_k u_k) / p, folded in worker order
        let mut u_mean = vec![0.0; d];
        for (k, shard) in shards.iter().enumerate() {
            let u = legacy_dense_epoch(
                shard, loss, &w, &z, eta, reg.lam1, reg.lam2, m_inner, &mut rngs[k],
            );
            for j in 0..d {
                u_mean[j] += u[j];
            }
        }
        for v in u_mean.iter_mut() {
            *v *= 1.0 / p as f64;
        }
        w.copy_from_slice(&u_mean);
    }
    w
}

#[test]
fn coordinator_trajectory_is_bit_identical_to_legacy_replay() {
    for (ds, _loss, reg, tag) in problems() {
        let model = if tag == "logistic" { Model::Logistic } else { Model::Lasso };
        let (p, epochs, m_inner, eta) = (2usize, 4usize, 150usize, 0.05f64);
        let cfg = PscopeConfig {
            p,
            outer_iters: epochs,
            m_inner,
            eta,
            reg,
            seed: 77,
            backend: WorkerBackend::RustDense,
            ..PscopeConfig::for_dataset("tiny", model)
        };
        let part = Partitioner::Uniform.split(&ds, p, 3);
        let out = train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap();
        let legacy = legacy_call_trajectory(
            &ds,
            &part.assignment,
            model.loss(),
            reg,
            eta,
            m_inner,
            77,
            epochs,
        );
        for j in 0..ds.d() {
            assert_eq!(
                out.w[j].to_bits(),
                legacy[j].to_bits(),
                "{tag} coord {j}: coordinator {} vs legacy replay {}",
                out.w[j],
                legacy[j]
            );
        }
    }
}

#[test]
fn explicit_loss_reg_overrides_reproduce_the_model_preset_bitwise() {
    // naming the same objective explicitly (loss = "logistic",
    // reg = "elasticnet") must be the identity — config plumbing only
    for (ds, loss, reg, tag) in problems() {
        let model = if tag == "logistic" { Model::Logistic } else { Model::Lasso };
        let base = PscopeConfig {
            p: 3,
            outer_iters: 4,
            reg,
            seed: 5,
            ..PscopeConfig::for_dataset("tiny", model)
        };
        let part = Partitioner::Uniform.split(&ds, 3, 1);
        let a = train_with(&ds, &part, &base, None, NetModel::zero()).unwrap();
        let explicit = PscopeConfig {
            loss: Some(loss),
            reg_kind: Some(RegKind::ElasticNet),
            ..base
        };
        let b = train_with(&ds, &part, &explicit, None, NetModel::zero()).unwrap();
        assert_eq!(a.w, b.w, "{tag}: explicit overrides perturbed the trajectory");
        assert_eq!(a.comm, b.comm, "{tag}: comm accounting diverged");
        for (x, y) in a.trace.points.iter().zip(&b.trace.points) {
            assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "{tag} epoch {}", x.epoch);
        }
    }
}
