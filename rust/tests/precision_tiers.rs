//! The two-tier precision contract (DESIGN.md §14), end to end.
//!
//! * `--precision exact` (the default) is the historical bit-for-bit f64
//!   path — every parity/accounting test in the suite pins it, and this
//!   file adds the knob-level statement: an explicit `exact` run is
//!   byte-identical to a run whose config never mentions the knob.
//! * `--precision fast` runs the dense inner epoch and the shard
//!   gradient through the f32 kernels with f64 carry. It is pinned by
//!   *tolerance*, never bits: per-epoch objectives and the final
//!   objective must track the exact twin to rel ≤ 1e-5 across the
//!   composite (loss, regularizer) matrix on both worker engines — and
//!   the tier is deterministic, so two fast runs agree bit for bit.
//! * The tier travels in the v8 `RunSpec` tail: a TCP fast run must
//!   reproduce the in-process fast run bit for bit, and a spec whose
//!   tier disagrees with the master's config is rejected before any
//!   training (the same preflight contract as the wire mode).

use std::time::Duration;

use pscope::config::{Model, Precision, PscopeConfig, RegKind, WorkerBackend};
use pscope::coordinator::remote::{serve_worker, MasterEndpoint, RunSpec};
use pscope::coordinator::train_with;
use pscope::data::source::DataSource;
use pscope::data::{synth, Dataset};
use pscope::loss::{Reg, SmoothLoss};
use pscope::metrics::Trace;
use pscope::net::NetModel;
use pscope::partition::Partitioner;

struct Scenario {
    tag: &'static str,
    ds: Dataset,
    loss: SmoothLoss,
    reg_kind: RegKind,
    reg: Reg,
    has_lazy_skip: bool,
}

/// The composite-objective matrix (the same four corners the
/// `objective_matrix` suite trains): every scalar-prox family plus the
/// group Lasso, whose inner epoch has no scalar kernel and falls back to
/// the exact dense sweep even in the fast tier (the shard gradient still
/// runs fast, so the run is tolerance-pinned, not bit-pinned).
fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            tag: "huber+l1",
            ds: synth::tiny(901).with_task(synth::Task::Regression).generate(),
            loss: SmoothLoss::Huber { delta: 1.0 },
            reg_kind: RegKind::L1,
            reg: Reg { lam1: 0.0, lam2: 1e-3 },
            has_lazy_skip: true,
        },
        Scenario {
            tag: "squared_hinge+elasticnet",
            ds: synth::tiny(902).generate(),
            loss: SmoothLoss::SquaredHinge,
            reg_kind: RegKind::ElasticNet,
            reg: Reg { lam1: 1e-4, lam2: 1e-4 },
            has_lazy_skip: true,
        },
        Scenario {
            tag: "logistic+group",
            ds: synth::tiny(903).generate(),
            loss: SmoothLoss::Logistic,
            reg_kind: RegKind::GroupLasso { group: 5 },
            reg: Reg { lam1: 0.0, lam2: 1e-3 },
            has_lazy_skip: false,
        },
        Scenario {
            tag: "squared+nonneg",
            ds: synth::tiny(904).with_task(synth::Task::Regression).generate(),
            loss: SmoothLoss::Squared,
            reg_kind: RegKind::NonnegL1,
            reg: Reg { lam1: 0.0, lam2: 1e-3 },
            has_lazy_skip: false,
        },
    ]
}

fn cfg_for(
    s: &Scenario,
    backend: WorkerBackend,
    epochs: usize,
    precision: Precision,
) -> PscopeConfig {
    PscopeConfig {
        p: 2,
        outer_iters: epochs,
        reg: s.reg,
        loss: Some(s.loss),
        reg_kind: Some(s.reg_kind),
        seed: 11,
        backend,
        precision,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    }
}

/// The fast tier's contract bound: per-epoch objectives within
/// rel ≤ 1e-5 of the exact twin's, epoch for epoch.
fn assert_traces_close(tag: &str, backend: WorkerBackend, exact: &Trace, fast: &Trace) {
    assert_eq!(
        exact.points.len(),
        fast.points.len(),
        "{tag} [{backend:?}]: trace lengths differ"
    );
    for (a, b) in exact.points.iter().zip(&fast.points) {
        let tol = 1e-5 * (1.0 + a.objective.abs());
        assert!(
            (a.objective - b.objective).abs() <= tol,
            "{tag} [{backend:?}] epoch {}: exact {} vs fast {} (tol {tol:e})",
            a.epoch,
            a.objective,
            b.objective
        );
    }
}

#[test]
fn fast_tier_tracks_exact_within_tolerance_on_both_engines() {
    for s in scenarios() {
        for backend in [WorkerBackend::RustSparse, WorkerBackend::RustDense] {
            let part = Partitioner::Uniform.split(&s.ds, 2, 3);
            let exact_cfg = cfg_for(&s, backend, 6, Precision::Exact);
            let fast_cfg = cfg_for(&s, backend, 6, Precision::Fast);
            let exact = train_with(&s.ds, &part, &exact_cfg, None, NetModel::zero()).unwrap();
            let fast = train_with(&s.ds, &part, &fast_cfg, None, NetModel::zero()).unwrap();
            assert_traces_close(s.tag, backend, &exact.trace, &fast.trace);
            if s.has_lazy_skip && backend == WorkerBackend::RustSparse {
                // lazy-skip regularizers keep the exact lazy inner epoch
                // even in the fast tier — the engine must still engage
                assert!(
                    fast.materializations > 0,
                    "{}: lazy engine never engaged under the fast tier",
                    s.tag
                );
            }
            let (pe, pf) = (exact.trace.last_objective(), fast.trace.last_objective());
            assert!(
                (pe - pf).abs() <= 1e-5 * (1.0 + pe.abs()),
                "{} [{backend:?}]: final objective exact {pe} vs fast {pf}",
                s.tag
            );
            // the tier is deterministic: a second fast run is bit-identical
            let fast2 = train_with(&s.ds, &part, &fast_cfg, None, NetModel::zero()).unwrap();
            for j in 0..fast.w.len() {
                assert_eq!(
                    fast.w[j].to_bits(),
                    fast2.w[j].to_bits(),
                    "{} [{backend:?}] coord {j}: fast tier not deterministic",
                    s.tag
                );
            }
        }
    }
}

#[test]
fn fast_tier_actually_engages_and_lazy_engine_survives_it() {
    // the knob must do something: on the dense backend a fast run's
    // iterate carries f32 rounding the exact run cannot have
    let scens = scenarios();
    let s = &scens[1]; // squared_hinge+elasticnet
    let part = Partitioner::Uniform.split(&s.ds, 2, 3);
    let exact = train_with(
        &s.ds,
        &part,
        &cfg_for(s, WorkerBackend::RustDense, 6, Precision::Exact),
        None,
        NetModel::zero(),
    )
    .unwrap();
    let fast = train_with(
        &s.ds,
        &part,
        &cfg_for(s, WorkerBackend::RustDense, 6, Precision::Fast),
        None,
        NetModel::zero(),
    )
    .unwrap();
    assert!(
        (0..exact.w.len()).any(|j| exact.w[j].to_bits() != fast.w[j].to_bits()),
        "{}: fast tier produced a bit-identical trajectory — knob not plumbed through?",
        s.tag
    );
    // the lazy sparse engine stays on its exact path inside a fast run
    // (only the shard gradient goes f32) — and it must still engage
    let lazy_fast = train_with(
        &s.ds,
        &part,
        &cfg_for(s, WorkerBackend::RustSparse, 6, Precision::Fast),
        None,
        NetModel::zero(),
    )
    .unwrap();
    assert!(
        lazy_fast.materializations > 0,
        "{}: lazy engine never engaged under the fast tier",
        s.tag
    );
}

#[test]
fn explicit_exact_is_byte_identical_to_the_default() {
    // `--precision exact` is the default: a config that never mentions
    // the knob and one that sets it explicitly are the same run, bit for
    // bit — no "off by default but different" drift
    let scens = scenarios();
    let s = &scens[0];
    let part = Partitioner::Uniform.split(&s.ds, 2, 3);
    let mut implicit_cfg = cfg_for(s, WorkerBackend::RustSparse, 4, Precision::Exact);
    implicit_cfg.precision = PscopeConfig::default().precision;
    let explicit_cfg = cfg_for(s, WorkerBackend::RustSparse, 4, Precision::Exact);
    let a = train_with(&s.ds, &part, &implicit_cfg, None, NetModel::zero()).unwrap();
    let b = train_with(&s.ds, &part, &explicit_cfg, None, NetModel::zero()).unwrap();
    for j in 0..a.w.len() {
        assert_eq!(a.w[j].to_bits(), b.w[j].to_bits(), "coord {j}");
    }
    assert_eq!(a.comm, b.comm);
}

#[test]
fn fast_tier_travels_the_wire_and_matches_inproc_bitwise() {
    // the v8 spec tail ships the tier: a TCP fast run must reproduce the
    // in-process fast run bit for bit (the tier is deterministic, so the
    // transport cannot introduce drift), for both a lazy-skip scenario
    // and a dense-fallback (group) one. Only classification presets here:
    // Synth workers regenerate the dataset from (name, seed), so the
    // `with_task(Regression)` scenarios are not wire-replayable.
    for (scen_idx, data_seed) in [(1usize, 902u64), (2usize, 903u64)] {
        let scens = scenarios();
        let s = &scens[scen_idx];
        let (part_seed, p) = (1u64, 2usize);
        let cfg = cfg_for(s, WorkerBackend::RustSparse, 3, Precision::Fast);
        let part = Partitioner::Uniform.split(&s.ds, p, part_seed);
        let inproc = train_with(&s.ds, &part, &cfg, None, NetModel::ten_gbe()).unwrap();

        let src = DataSource::Synth { name: "tiny".into(), seed: data_seed };
        let spec =
            RunSpec::derive(&s.ds, &part, &cfg, &src, "uniform", part_seed, None).unwrap();
        assert_eq!(spec.precision, Precision::Fast, "{}: tier lost in derive", s.tag);
        let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap().to_string();
        let workers: Vec<_> = (0..p)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || serve_worker(&addr, Duration::from_secs(30)))
            })
            .collect();
        let tcp = ep
            .train(&s.ds, &part, &cfg, NetModel::ten_gbe(), &spec, Duration::from_secs(30))
            .unwrap();
        for h in workers {
            h.join().unwrap().unwrap();
        }
        for j in 0..inproc.w.len() {
            assert_eq!(
                inproc.w[j].to_bits(),
                tcp.w[j].to_bits(),
                "{} coord {j}: inproc {} vs tcp {}",
                s.tag,
                inproc.w[j],
                tcp.w[j]
            );
        }
        for (a, b) in inproc.trace.points.iter().zip(&tcp.trace.points) {
            assert_eq!(
                a.objective.to_bits(),
                b.objective.to_bits(),
                "{} epoch {}",
                s.tag,
                a.epoch
            );
        }
    }
}

#[test]
fn mismatched_spec_precision_is_rejected_before_training() {
    // preflight contract: a spec whose tier disagrees with the master's
    // config fails on the caller thread, before any worker trains
    let ds = synth::tiny(33).generate();
    let cfg = PscopeConfig {
        p: 1,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 1, 1);
    let src = DataSource::Synth { name: "tiny".into(), seed: 33 };
    let mut spec = RunSpec::derive(&ds, &part, &cfg, &src, "uniform", 1, None).unwrap();
    assert_eq!(spec.precision, Precision::Exact);
    spec.precision = Precision::Fast;
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let err = ep
        .train(&ds, &part, &cfg, NetModel::zero(), &spec, Duration::from_secs(5))
        .unwrap_err();
    assert!(
        format!("{err}").contains("precision"),
        "unexpected error: {err}"
    );
}
