//! Interconnect accounting: a `train_with` run moves exactly O(1)
//! communication rounds and O(p·d) bytes per outer epoch — the paper's
//! communication-efficiency claim (§5, contrasted with minibatch methods'
//! O(n/b) rounds), pinned to the byte — and the real-TCP transport
//! reproduces both the trajectory and the byte totals bit-for-bit, with
//! the meter fed by actual bytes on the wire.

use std::time::{Duration, Instant};

use pscope::config::{Model, PscopeConfig, WireMode};
use pscope::coordinator::protocol::{vec_bytes, MSG_HEADER_BYTES};
use pscope::coordinator::remote::{serve_worker, MasterEndpoint, RunSpec};
use pscope::coordinator::{train_with, train_with_opts};
use pscope::data::source::DataSource;
use pscope::data::synth;
use pscope::loss::Reg;
use pscope::net::{frame, NetModel};
use pscope::partition::Partitioner;

/// Exact wire bytes of one outer epoch with `p` workers over `d` features:
/// Broadcast(w) + ShardGrad(zsum, count) + FullGrad(z) + LocalIterate(u,
/// compute_s, materializations) per worker.
fn epoch_bytes(p: usize, d: usize) -> u64 {
    p as u64 * (vec_bytes(d) + (vec_bytes(d) + 8) + vec_bytes(d) + (vec_bytes(d) + 16))
}

fn run(ds: &pscope::data::Dataset, p: usize, epochs: usize) -> (u64, u64) {
    let cfg = PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(ds, p, 1);
    let out = train_with(ds, &part, &cfg, None, NetModel::zero()).unwrap();
    out.comm
}

#[test]
fn bytes_are_exactly_4pd_per_epoch() {
    let ds = synth::tiny(21).generate();
    let d = ds.d();
    for (p, epochs) in [(1usize, 2usize), (2, 3), (4, 5)] {
        let (bytes, _) = run(&ds, p, epochs);
        // + one Stop header per worker at shutdown
        let expect = epochs as u64 * epoch_bytes(p, d) + p as u64 * MSG_HEADER_BYTES;
        assert_eq!(bytes, expect, "p={p} epochs={epochs}");
    }
}

#[test]
fn rounds_are_constant_per_epoch() {
    // O(1) rounds per epoch: exactly 4 messages per worker per epoch
    // (2 broadcasts down, 2 reductions up), independent of epoch count.
    let ds = synth::tiny(22).generate();
    for (p, epochs) in [(2usize, 2usize), (2, 6), (3, 4)] {
        let (_, msgs) = run(&ds, p, epochs);
        let expect = epochs as u64 * 4 * p as u64 + p as u64; // + Stop each
        assert_eq!(msgs, expect, "p={p} epochs={epochs}");
    }
}

#[test]
fn per_epoch_bytes_scale_with_d_not_n() {
    // Doubling the instance count must not change per-epoch wire traffic:
    // the protocol only ever moves d-sized vectors (this is the entire
    // contrast with the O(n)-per-epoch minibatch baselines).
    let small = synth::tiny(23).generate();
    let big = synth::tiny(23).with_n(2 * small.n()).generate();
    assert_eq!(small.d(), big.d());
    let epochs = 3;
    let (b_small, m_small) = run(&small, 4, epochs);
    let (b_big, m_big) = run(&big, 4, epochs);
    assert_eq!(b_small, b_big, "per-epoch bytes depend on n");
    assert_eq!(m_small, m_big, "per-epoch rounds depend on n");
}

// ---- real-TCP transport: parity with the simulation ---------------------

/// Spin up a loopback cluster — master endpoint + `p` worker *threads*
/// each running the genuine `pscope worker` client over real sockets —
/// and train.
fn tcp_train(
    ds: &pscope::data::Dataset,
    part: &pscope::partition::Partition,
    cfg: &PscopeConfig,
    data_seed: u64,
    part_seed: u64,
) -> pscope::coordinator::TrainOutput {
    let src = DataSource::Synth { name: "tiny".into(), seed: data_seed };
    let spec = RunSpec::derive(ds, part, cfg, &src, "uniform", part_seed, None).unwrap();
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..part.p())
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || serve_worker(&addr, Duration::from_secs(30)))
        })
        .collect();
    let out = ep
        .train(ds, part, cfg, NetModel::ten_gbe(), &spec, Duration::from_secs(30))
        .unwrap();
    for h in workers {
        h.join().unwrap().unwrap();
    }
    out
}

#[test]
fn tcp_loopback_is_bit_identical_to_inproc() {
    // Same seed/config/partition ⇒ the TCP run must reproduce the InProc
    // run exactly: final iterate bit-for-bit, meter totals to the byte.
    let (data_seed, part_seed, p, epochs) = (21u64, 1u64, 3usize, 4usize);
    let ds = synth::tiny(data_seed).generate();
    let cfg = PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let inproc = train_with(&ds, &part, &cfg, None, NetModel::ten_gbe()).unwrap();
    let tcp = tcp_train(&ds, &part, &cfg, data_seed, part_seed);

    assert_eq!(inproc.w.len(), tcp.w.len());
    for j in 0..inproc.w.len() {
        assert_eq!(
            inproc.w[j].to_bits(),
            tcp.w[j].to_bits(),
            "coord {j}: inproc {} vs tcp {}",
            inproc.w[j],
            tcp.w[j]
        );
    }
    assert_eq!(inproc.epochs_run, tcp.epochs_run);
    assert_eq!(inproc.materializations, tcp.materializations);
    assert_eq!(inproc.comm, tcp.comm, "byte-meter totals differ across transports");
    // per-epoch objectives equal bit-for-bit too (same trace shape)
    assert_eq!(inproc.trace.points.len(), tcp.trace.points.len());
    for (a, b) in inproc.trace.points.iter().zip(&tcp.trace.points) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "epoch {}", a.epoch);
        assert_eq!((a.comm_bytes, a.comm_msgs), (b.comm_bytes, b.comm_msgs), "epoch {}", a.epoch);
    }
}

#[test]
fn tcp_measured_bytes_equal_modeled_accounting_exactly() {
    // Over TCP the meter is fed by actual frame sizes; the total must
    // still equal the modeled 4·p·d·8 (+headers) per epoch, + Stop each.
    let (p, epochs) = (2usize, 3usize);
    let ds = synth::tiny(27).generate();
    let d = ds.d();
    let cfg = PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, p, 1);
    let out = tcp_train(&ds, &part, &cfg, 27, 1);
    let expect_bytes = epochs as u64 * epoch_bytes(p, d) + p as u64 * MSG_HEADER_BYTES;
    let expect_msgs = epochs as u64 * 4 * p as u64 + p as u64;
    assert_eq!(out.comm.0, expect_bytes, "measured wire bytes != modeled accounting");
    assert_eq!(out.comm.1, expect_msgs, "measured message count != modeled accounting");
}

#[test]
fn killed_tcp_worker_is_protocol_error_within_timeout_not_hang() {
    // One real worker + one impostor that completes the handshake and then
    // drops the connection. The master must surface Error::Protocol fast
    // (the WorkerDown mapping), and the surviving worker must drain
    // cleanly — no hung reduce loop, no leaked thread.
    let (data_seed, part_seed, p) = (26u64, 1u64, 2usize);
    let ds = synth::tiny(data_seed).generate();
    let cfg = PscopeConfig {
        p,
        outer_iters: 50,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let src = DataSource::Synth { name: "tiny".into(), seed: data_seed };
    let spec = RunSpec::derive(&ds, &part, &cfg, &src, "uniform", part_seed, None).unwrap();
    let ep = MasterEndpoint::bind("127.0.0.1:0").unwrap();
    let addr = ep.local_addr().unwrap().to_string();

    let survivor = {
        let addr = addr.clone();
        std::thread::spawn(move || serve_worker(&addr, Duration::from_secs(30)))
    };
    let impostor = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        let setup = match frame::read_frame(&mut s).unwrap() {
            frame::FrameRead::Frame(f) => f,
            other => panic!("expected Setup, got {other:?}"),
        };
        let (tag, _epoch, k, _payload) = frame::parts(&setup).unwrap();
        assert_eq!(tag, frame::TAG_SETUP);
        frame::write_frame(&mut s, &frame::encode_control(frame::TAG_READY, k, &[])).unwrap();
        // die mid-epoch without a word — the connection drop is the signal
    });

    let start = Instant::now();
    let err = ep
        .train(&ds, &part, &cfg, NetModel::zero(), &spec, Duration::from_secs(30))
        .expect_err("a dead worker must fail the run");
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "master took {:?} to notice the dead worker",
        start.elapsed()
    );
    assert!(
        matches!(err, pscope::error::Error::Protocol(_)),
        "expected Error::Protocol, got {err:?}"
    );
    assert!(format!("{err}").contains("died"), "unexpected message: {err}");

    impostor.join().unwrap();
    // the surviving worker drains on Stop/EOF — a clean exit, not an error
    survivor.join().unwrap().unwrap();
}

// ---- sparse wire (SPEC_VERSION 7): --wire auto parity -------------------

/// Bit-identical w / objectives / epoch count comparisons between two runs.
fn assert_same_trajectory(
    a: &pscope::coordinator::TrainOutput,
    b: &pscope::coordinator::TrainOutput,
    what: &str,
) {
    assert_eq!(a.w.len(), b.w.len(), "{what}: dimension");
    for j in 0..a.w.len() {
        assert_eq!(a.w[j].to_bits(), b.w[j].to_bits(), "{what}: coord {j}");
    }
    assert_eq!(a.epochs_run, b.epochs_run, "{what}: epoch count");
    assert_eq!(a.trace.points.len(), b.trace.points.len(), "{what}: trace shape");
    for (x, y) in a.trace.points.iter().zip(&b.trace.points) {
        assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "{what}: epoch {}", x.epoch);
    }
}

#[test]
fn tcp_auto_wire_is_bit_identical_to_dense_and_strictly_cheaper() {
    // The sparse arm is a pure re-encoding: a `--wire auto` run over real
    // TCP must walk the exact trajectory of the legacy `--wire dense`
    // InProc run (same seed/partition), while the byte meter strictly
    // shrinks — the cold start alone guarantees it (w0 = 0 makes the
    // first Broadcast all-zero, 17 bytes sparse vs 8·d dense), and the
    // large lam1 keeps later iterates sparse too.
    let (data_seed, part_seed, p, epochs) = (29u64, 1u64, 2usize, 4usize);
    let ds = synth::tiny(data_seed).generate();
    let mk = |wire: WireMode| PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 5e-2, lam2: 1e-3 },
        seed: 5,
        wire,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, p, part_seed);
    let dense = train_with(&ds, &part, &mk(WireMode::Dense), None, NetModel::ten_gbe()).unwrap();
    let auto_ip = train_with(&ds, &part, &mk(WireMode::Auto), None, NetModel::ten_gbe()).unwrap();
    let auto_tcp = tcp_train(&ds, &part, &mk(WireMode::Auto), data_seed, part_seed);

    assert_same_trajectory(&dense, &auto_ip, "inproc auto vs inproc dense");
    assert_same_trajectory(&dense, &auto_tcp, "tcp auto vs inproc dense");
    // InProc charges wire_bytes_for(Auto); TCP counts actual frame bytes.
    // The codec's length identity makes them the same meter.
    assert_eq!(auto_ip.comm, auto_tcp.comm, "auto-mode meter differs across transports");
    // strictly fewer bytes, same message count
    assert!(
        auto_tcp.comm.0 < dense.comm.0,
        "auto {} bytes !< dense {} bytes",
        auto_tcp.comm.0,
        dense.comm.0
    );
    assert_eq!(auto_tcp.comm.1, dense.comm.1, "auto changed the message count");
}

#[test]
fn auto_wire_costs_dense_bytes_on_dense_iterates() {
    // With a dense warm start and lam1 ≈ 0 no vector ever sparsifies, so
    // encode-time selection picks the dense arm for every frame and the
    // auto run is byte-for-byte the dense run — compression never costs.
    let ds = synth::tiny(31).generate();
    let d = ds.d();
    let mk = |wire: WireMode| PscopeConfig {
        p: 2,
        outer_iters: 3,
        reg: Reg { lam1: 1e-9, lam2: 1e-3 },
        seed: 5,
        wire,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 2, 1);
    let w0: Vec<f64> = (0..d).map(|j| 0.1 + 0.01 * j as f64).collect();
    let net = NetModel::ten_gbe();
    let dense =
        train_with_opts(&ds, &part, &mk(WireMode::Dense), None, net, Some(&w0)).unwrap();
    let auto = train_with_opts(&ds, &part, &mk(WireMode::Auto), None, net, Some(&w0)).unwrap();
    assert_same_trajectory(&dense, &auto, "auto vs dense, dense iterates");
    assert_eq!(auto.comm, dense.comm, "auto charged different bytes on dense payloads");
}

#[test]
fn wire_time_uses_metered_totals() {
    // The trace's modeled wire time must equal the NetModel applied to the
    // metered counters — no hidden traffic, no double counting.
    let ds = synth::tiny(24).generate();
    let net = NetModel { latency_s: 1e-4, bandwidth_bps: 1e8 };
    let cfg = PscopeConfig {
        p: 2,
        outer_iters: 4,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 2, 1);
    let out = train_with(&ds, &part, &cfg, None, net).unwrap();
    let last = out.trace.points.last().unwrap();
    let expect = net.wire_time(last.comm_bytes, last.comm_msgs);
    assert!(
        (last.net_s - expect).abs() < 1e-12,
        "net_s {} vs model {}",
        last.net_s,
        expect
    );
}
