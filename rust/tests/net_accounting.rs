//! Simulated-interconnect accounting: a `train_with` run moves exactly
//! O(1) communication rounds and O(p·d) bytes per outer epoch — the
//! paper's communication-efficiency claim (§5, contrasted with minibatch
//! methods' O(n/b) rounds), pinned to the byte.

use pscope::config::{Model, PscopeConfig};
use pscope::coordinator::protocol::{vec_bytes, MSG_HEADER_BYTES};
use pscope::coordinator::train_with;
use pscope::data::synth;
use pscope::loss::Reg;
use pscope::net::NetModel;
use pscope::partition::Partitioner;

/// Exact wire bytes of one outer epoch with `p` workers over `d` features:
/// Broadcast(w) + ShardGrad(zsum, count) + FullGrad(z) + LocalIterate(u,
/// compute_s, materializations) per worker.
fn epoch_bytes(p: usize, d: usize) -> u64 {
    p as u64 * (vec_bytes(d) + (vec_bytes(d) + 8) + vec_bytes(d) + (vec_bytes(d) + 16))
}

fn run(ds: &pscope::data::Dataset, p: usize, epochs: usize) -> (u64, u64) {
    let cfg = PscopeConfig {
        p,
        outer_iters: epochs,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(ds, p, 1);
    let out = train_with(ds, &part, &cfg, None, NetModel::zero()).unwrap();
    out.comm
}

#[test]
fn bytes_are_exactly_4pd_per_epoch() {
    let ds = synth::tiny(21).generate();
    let d = ds.d();
    for (p, epochs) in [(1usize, 2usize), (2, 3), (4, 5)] {
        let (bytes, _) = run(&ds, p, epochs);
        // + one Stop header per worker at shutdown
        let expect = epochs as u64 * epoch_bytes(p, d) + p as u64 * MSG_HEADER_BYTES;
        assert_eq!(bytes, expect, "p={p} epochs={epochs}");
    }
}

#[test]
fn rounds_are_constant_per_epoch() {
    // O(1) rounds per epoch: exactly 4 messages per worker per epoch
    // (2 broadcasts down, 2 reductions up), independent of epoch count.
    let ds = synth::tiny(22).generate();
    for (p, epochs) in [(2usize, 2usize), (2, 6), (3, 4)] {
        let (_, msgs) = run(&ds, p, epochs);
        let expect = epochs as u64 * 4 * p as u64 + p as u64; // + Stop each
        assert_eq!(msgs, expect, "p={p} epochs={epochs}");
    }
}

#[test]
fn per_epoch_bytes_scale_with_d_not_n() {
    // Doubling the instance count must not change per-epoch wire traffic:
    // the protocol only ever moves d-sized vectors (this is the entire
    // contrast with the O(n)-per-epoch minibatch baselines).
    let small = synth::tiny(23).generate();
    let big = synth::tiny(23).with_n(2 * small.n()).generate();
    assert_eq!(small.d(), big.d());
    let epochs = 3;
    let (b_small, m_small) = run(&small, 4, epochs);
    let (b_big, m_big) = run(&big, 4, epochs);
    assert_eq!(b_small, b_big, "per-epoch bytes depend on n");
    assert_eq!(m_small, m_big, "per-epoch rounds depend on n");
}

#[test]
fn wire_time_uses_metered_totals() {
    // The trace's modeled wire time must equal the NetModel applied to the
    // metered counters — no hidden traffic, no double counting.
    let ds = synth::tiny(24).generate();
    let net = NetModel { latency_s: 1e-4, bandwidth_bps: 1e8 };
    let cfg = PscopeConfig {
        p: 2,
        outer_iters: 4,
        reg: Reg { lam1: 1e-3, lam2: 1e-3 },
        seed: 5,
        ..PscopeConfig::for_dataset("tiny", Model::Logistic)
    };
    let part = Partitioner::Uniform.split(&ds, 2, 1);
    let out = train_with(&ds, &part, &cfg, None, net).unwrap();
    let last = out.trace.points.last().unwrap();
    let expect = net.wire_time(last.comm_bytes, last.comm_msgs);
    assert!(
        (last.net_s - expect).abs() < 1e-12,
        "net_s {} vs model {}",
        last.net_s,
        expect
    );
}
