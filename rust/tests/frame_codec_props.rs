//! Property tests for the binary wire codec (`net::frame`): encode/decode
//! roundtrip for every `ToWorker`/`ToMaster` variant — including NaN
//! payloads, ±inf, signed zeros and arbitrary bit patterns — and the
//! frame-length == `wire_bytes()` identity that makes the TCP byte meter
//! equal the modeled accounting. The v7 sparse arm gets the same
//! treatment under `WireMode::Auto`: bit-exact roundtrips through the
//! mode-blind decoder, the per-mode length identity, every-prefix
//! truncation rejection, and loud `Error::Protocol` rejection of
//! unsorted / duplicate / out-of-range sparse indices.

use pscope::config::{Model, PscopeConfig, WireMode};
use pscope::coordinator::protocol::{ToMaster, ToWorker};
use pscope::error::Error;
use pscope::coordinator::remote::RunSpec;
use pscope::coordinator::serve::{
    decode_job_done, decode_job_setup, encode_job_done, encode_job_setup, PoolWorkerStats,
};
use pscope::data::source::DataSource;
use pscope::data::synth;
use pscope::net::frame::{self, FrameRead};
use pscope::partition::Partitioner;
use pscope::rng::Rng;
use pscope::testkit::prop;

/// Adversarial float generator: specials, arbitrary bit patterns
/// (NaN payloads, subnormals), and plain finite values.
fn arb_f64(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => 0.0,
        4 => -0.0,
        5 => f64::from_bits(rng.next_u64()),
        _ => rng.range(-1e9, 1e9),
    }
}

fn arb_vec(rng: &mut Rng, shrink: u32) -> Vec<f64> {
    let cap = 64usize >> shrink.min(3);
    let len = rng.below(cap + 1);
    (0..len).map(|_| arb_f64(rng)).collect()
}

/// Mostly-zero vector: the payload shape the sparse arm exists for. The
/// planted entries still draw from [`arb_f64`], so NaN payloads, ±0.0
/// and arbitrary bit patterns travel through the sparse arm too.
fn arb_sparse_vec(rng: &mut Rng, shrink: u32) -> Vec<f64> {
    let cap = 96usize >> shrink.min(3);
    let len = rng.below(cap + 1);
    let mut v = vec![0.0f64; len];
    if len == 0 {
        return v;
    }
    for _ in 0..rng.below(len / 3 + 1) {
        let i = rng.below(len);
        v[i] = arb_f64(rng);
    }
    v
}

/// Bitwise comparison (NaN-safe — `==` would reject equal NaNs).
fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn arb_to_worker(rng: &mut Rng, shrink: u32) -> ToWorker {
    match rng.below(3) {
        0 => ToWorker::Broadcast { epoch: rng.below(1 << 20), w: arb_vec(rng, shrink) },
        1 => ToWorker::FullGrad { epoch: rng.below(1 << 20), z: arb_vec(rng, shrink) },
        _ => ToWorker::Stop,
    }
}

fn arb_to_master(rng: &mut Rng, shrink: u32) -> ToMaster {
    match rng.below(3) {
        0 => ToMaster::ShardGrad {
            worker: rng.below(64),
            epoch: rng.below(1 << 20),
            zsum: arb_vec(rng, shrink),
            count: rng.below(1 << 30),
        },
        1 => ToMaster::LocalIterate {
            worker: rng.below(64),
            epoch: rng.below(1 << 20),
            u: arb_vec(rng, shrink),
            compute_s: arb_f64(rng),
            materializations: rng.next_u64(),
        },
        _ => ToMaster::WorkerDown { worker: rng.below(64) },
    }
}

fn same_to_worker(a: &ToWorker, b: &ToWorker) -> bool {
    match (a, b) {
        (ToWorker::Broadcast { epoch: e1, w: v1 }, ToWorker::Broadcast { epoch: e2, w: v2 }) => {
            e1 == e2 && bits(v1) == bits(v2)
        }
        (ToWorker::FullGrad { epoch: e1, z: v1 }, ToWorker::FullGrad { epoch: e2, z: v2 }) => {
            e1 == e2 && bits(v1) == bits(v2)
        }
        (ToWorker::Stop, ToWorker::Stop) => true,
        _ => false,
    }
}

fn same_to_master(a: &ToMaster, b: &ToMaster) -> bool {
    match (a, b) {
        (
            ToMaster::ShardGrad { worker: w1, epoch: e1, zsum: v1, count: c1 },
            ToMaster::ShardGrad { worker: w2, epoch: e2, zsum: v2, count: c2 },
        ) => w1 == w2 && e1 == e2 && c1 == c2 && bits(v1) == bits(v2),
        (
            ToMaster::LocalIterate {
                worker: w1,
                epoch: e1,
                u: v1,
                compute_s: s1,
                materializations: m1,
            },
            ToMaster::LocalIterate {
                worker: w2,
                epoch: e2,
                u: v2,
                compute_s: s2,
                materializations: m2,
            },
        ) => {
            w1 == w2 && e1 == e2 && m1 == m2 && s1.to_bits() == s2.to_bits() && bits(v1) == bits(v2)
        }
        (ToMaster::WorkerDown { worker: w1 }, ToMaster::WorkerDown { worker: w2 }) => w1 == w2,
        _ => false,
    }
}

#[test]
fn prop_to_worker_roundtrip_and_length_identity() {
    prop::check("ToWorker codec", 300, |rng, shrink| {
        let msg = arb_to_worker(rng, shrink);
        let buf = frame::encode_to_worker(&msg);
        if buf.len() as u64 != msg.wire_bytes() {
            return prop::that(
                false,
                format!("encoded {} bytes != wire_bytes {} for {msg:?}", buf.len(), msg.wire_bytes()),
            );
        }
        match frame::decode_to_worker(&buf) {
            Ok(back) => prop::that(
                same_to_worker(&msg, &back),
                format!("roundtrip mismatch: {msg:?} vs {back:?}"),
            ),
            Err(e) => prop::that(false, format!("decode failed: {e} for {msg:?}")),
        }
    });
}

#[test]
fn prop_to_master_roundtrip_and_length_identity() {
    prop::check("ToMaster codec", 300, |rng, shrink| {
        let msg = arb_to_master(rng, shrink);
        let buf = frame::encode_to_master(&msg);
        if buf.len() as u64 != msg.wire_bytes() {
            return prop::that(
                false,
                format!("encoded {} bytes != wire_bytes {} for {msg:?}", buf.len(), msg.wire_bytes()),
            );
        }
        match frame::decode_to_master(&buf) {
            Ok(back) => prop::that(
                same_to_master(&msg, &back),
                format!("roundtrip mismatch: {msg:?} vs {back:?}"),
            ),
            Err(e) => prop::that(false, format!("decode failed: {e} for {msg:?}")),
        }
    });
}

#[test]
fn prop_framed_streams_roundtrip_and_reject_truncation() {
    prop::check("framed stream", 120, |rng, shrink| {
        let n_msgs = 1 + rng.below(6);
        let msgs: Vec<ToMaster> = (0..n_msgs).map(|_| arb_to_master(rng, shrink)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            frame::write_frame(&mut wire, &frame::encode_to_master(m)).unwrap();
        }
        // the full stream reads back message-for-message, then clean EOF
        let mut cur = std::io::Cursor::new(&wire[..]);
        for (i, m) in msgs.iter().enumerate() {
            let f = match frame::read_frame(&mut cur) {
                Ok(FrameRead::Frame(f)) => f,
                other => return prop::that(false, format!("msg {i}: expected frame, got {other:?}")),
            };
            let back = match frame::decode_to_master(&f) {
                Ok(b) => b,
                Err(e) => return prop::that(false, format!("msg {i}: decode failed: {e}")),
            };
            if !same_to_master(m, &back) {
                return prop::that(false, format!("msg {i}: {m:?} vs {back:?}"));
            }
        }
        if !matches!(frame::read_frame(&mut cur), Ok(FrameRead::Eof)) {
            return prop::that(false, "no clean EOF at stream end".to_string());
        }
        // cutting the stream anywhere mid-frame must be an error, never a
        // silent truncation: drop 1..=8 trailing bytes (every frame is
        // ≥ 24 bytes, so the cut always lands inside the final frame)
        let cut = wire.len() - (1 + rng.below(8));
        let mut cur = std::io::Cursor::new(&wire[..cut]);
        loop {
            match frame::read_frame(&mut cur) {
                Ok(FrameRead::Frame(_)) => continue, // earlier intact frames are fine
                Ok(FrameRead::Eof) => {
                    // only legal if the cut landed exactly on a frame
                    // boundary — impossible here: we removed at least one
                    // byte of the final frame
                    return prop::that(false, format!("truncated stream (cut at {cut}) read as clean EOF"));
                }
                Ok(FrameRead::TimedOut) => {
                    return prop::that(false, "cursor cannot time out".to_string())
                }
                Err(_) => return prop::that(true, ""),
            }
        }
    });
}

#[test]
fn prop_auto_mode_roundtrip_and_length_identity() {
    prop::check("auto-mode codec", 300, |rng, shrink| {
        // bias toward sparse payloads so the sparse arm is actually
        // exercised; dense/empty/full-density vectors still appear
        let v = if rng.below(3) == 0 { arb_vec(rng, shrink) } else { arb_sparse_vec(rng, shrink) };
        let epoch = rng.below(1 << 20);
        let msg = match rng.below(2) {
            0 => ToWorker::Broadcast { epoch, w: v.clone() },
            _ => ToWorker::FullGrad { epoch, z: v.clone() },
        };
        let auto = msg.wire_bytes_for(WireMode::Auto);
        let buf = frame::encode_to_worker_mode(&msg, WireMode::Auto);
        if buf.len() as u64 != auto {
            return prop::that(
                false,
                format!("encoded {} bytes != wire_bytes_for(Auto) {auto} for {msg:?}", buf.len()),
            );
        }
        if auto > msg.wire_bytes() {
            return prop::that(false, format!("auto charge {auto} exceeds dense for {msg:?}"));
        }
        let back = match frame::decode_to_worker(&buf) {
            Ok(b) => b,
            Err(e) => return prop::that(false, format!("decode failed: {e} for {msg:?}")),
        };
        if !same_to_worker(&msg, &back) {
            return prop::that(false, format!("roundtrip mismatch: {msg:?} vs {back:?}"));
        }
        // the worker→master leg with the same vector as the local iterate
        let up = ToMaster::LocalIterate {
            worker: rng.below(64),
            epoch,
            u: v,
            compute_s: arb_f64(rng),
            materializations: rng.next_u64(),
        };
        let up_auto = up.wire_bytes_for(WireMode::Auto);
        let ubuf = frame::encode_to_master_mode(&up, WireMode::Auto);
        if ubuf.len() as u64 != up_auto {
            return prop::that(
                false,
                format!("encoded {} != wire_bytes_for(Auto) {up_auto} for {up:?}", ubuf.len()),
            );
        }
        match frame::decode_to_master(&ubuf) {
            Ok(b) => prop::that(
                same_to_master(&up, &b),
                format!("roundtrip mismatch: {up:?} vs {b:?}"),
            ),
            Err(e) => prop::that(false, format!("decode failed: {e} for {up:?}")),
        }
    });
}

#[test]
fn prop_auto_frame_rejects_every_truncation() {
    prop::check("auto-frame truncation", 200, |rng, shrink| {
        let mut w = arb_sparse_vec(rng, shrink);
        if w.len() < 8 {
            w = vec![0.0; 8];
        }
        let msg = ToWorker::Broadcast { epoch: rng.below(1 << 20), w };
        let buf = frame::encode_to_worker_mode(&msg, WireMode::Auto);
        // every strict prefix must fail: the header's length field no
        // longer matches the bytes on hand, so neither the stream reader
        // nor the decoder can be fooled into a silent prefix-read
        let cut = rng.below(buf.len());
        if frame::decode_to_worker(&buf[..cut]).is_ok() {
            return prop::that(false, format!("prefix of {cut}/{} bytes decoded", buf.len()));
        }
        let mut cur = std::io::Cursor::new(&buf[..cut]);
        match frame::read_frame(&mut cur) {
            Ok(FrameRead::Frame(_)) => {
                prop::that(false, format!("truncated frame ({cut}/{} bytes) read", buf.len()))
            }
            // an empty stream is a clean EOF; any other cut is mid-frame
            Ok(FrameRead::Eof) => prop::that(cut == 0, format!("cut {cut} read as clean EOF")),
            Ok(FrameRead::TimedOut) => prop::that(false, "cursor cannot time out".to_string()),
            Err(_) => prop::that(true, ""),
        }
    });
}

#[test]
fn prop_sparse_index_corruption_is_protocol_error() {
    prop::check("sparse index validation", 200, |rng, _shrink| {
        // exactly two nonzeros, planted in separate halves so their
        // indices are strictly increasing and the sparse arm always wins
        let len = 32 + rng.below(64);
        let mut v = vec![0.0f64; len];
        let i = rng.below(len / 2);
        let j = len / 2 + rng.below(len - len / 2);
        v[i] = 1.0 + rng.range(0.0, 1.0);
        v[j] = -1.0 - rng.range(0.0, 1.0);
        let msg = ToWorker::Broadcast { epoch: 0, w: v };
        let mut buf = frame::encode_to_worker_mode(&msg, WireMode::Auto);
        if (buf.len() - 24) % 8 == 0 {
            return prop::that(false, "expected the sparse arm".to_string());
        }
        // entry 0's index lives at frame offset 24 (header) + 17 (sparse
        // preamble); entry 1's one 12-byte stride later
        let e0 = 24 + 17;
        let e1 = e0 + 12;
        let mode = rng.below(3);
        match mode {
            // duplicate: entry 0 repeats entry 1's index
            0 => buf[e0..e0 + 4].copy_from_slice(&(j as u32).to_le_bytes()),
            // unsorted: swap the two indices (strictly decreasing)
            1 => {
                buf[e0..e0 + 4].copy_from_slice(&(j as u32).to_le_bytes());
                buf[e1..e1 + 4].copy_from_slice(&(i as u32).to_le_bytes());
            }
            // out of range: idx == d
            _ => buf[e0..e0 + 4].copy_from_slice(&(len as u32).to_le_bytes()),
        }
        match frame::decode_to_worker(&buf) {
            Err(Error::Protocol(_)) => prop::that(true, ""),
            other => prop::that(
                false,
                format!("corruption mode {mode}: expected Error::Protocol, got {other:?}"),
            ),
        }
    });
}

/// A real spec (derived, not hand-built) for the serve-pool codec props.
fn demo_spec(seed: u64) -> RunSpec {
    let ds = synth::tiny(seed).generate();
    let cfg = PscopeConfig::for_dataset("tiny", Model::Logistic);
    let part = Partitioner::parse("uniform").unwrap().split(&ds, cfg.p, seed);
    let source = DataSource::Synth { name: "tiny".into(), seed };
    RunSpec::derive(&ds, &part, &cfg, &source, "uniform", seed, None).unwrap()
}

#[test]
fn prop_job_setup_roundtrip_exact_bits() {
    let spec = demo_spec(7);
    prop::check("JobSetup codec", 200, |rng, shrink| {
        let job_idx = rng.next_u64();
        let w0 = if rng.below(4) == 0 { None } else { Some(arb_vec(rng, shrink)) };
        let buf = encode_job_setup(job_idx, &spec, w0.as_deref());
        let (idx, back, back_w0) = match decode_job_setup(&buf) {
            Ok(t) => t,
            Err(e) => return prop::that(false, format!("decode failed: {e}")),
        };
        if idx != job_idx {
            return prop::that(false, format!("job_idx {job_idx} decoded as {idx}"));
        }
        if back != spec {
            return prop::that(false, "RunSpec mangled in transit".to_string());
        }
        match (&w0, &back_w0) {
            (None, None) => prop::that(true, ""),
            (Some(a), Some(b)) => prop::that(
                bits(a) == bits(b),
                format!("w0 bits mangled: {:x?} vs {:x?}", bits(a), bits(b)),
            ),
            _ => prop::that(
                false,
                format!("w0 presence mangled: sent {:?}, got {:?}", w0.is_some(), back_w0.is_some()),
            ),
        }
    });
}

#[test]
fn prop_job_setup_rejects_every_truncation() {
    let spec = demo_spec(11);
    prop::check("JobSetup truncation", 200, |rng, shrink| {
        // always ship a warm start here so the tail is non-trivial
        let w0 = arb_vec(rng, shrink);
        let buf = encode_job_setup(rng.below(1 << 20) as u64, &spec, Some(&w0));
        // any strict prefix must fail — no silent prefix-train
        let cut = rng.below(buf.len());
        if decode_job_setup(&buf[..cut]).is_ok() {
            return prop::that(false, format!("prefix of {cut}/{} bytes decoded", buf.len()));
        }
        // and so must trailing garbage
        let mut long = buf;
        long.push(rng.below(256) as u8);
        prop::that(decode_job_setup(&long).is_err(), "trailing byte accepted".to_string())
    });
}

#[test]
fn prop_job_done_roundtrip_and_length() {
    prop::check("JobDone codec", 300, |rng, _shrink| {
        let stats = PoolWorkerStats {
            shard_loads: rng.next_u64(),
            rows_read: rng.next_u64(),
            jobs_done: rng.next_u64(),
        };
        let buf = encode_job_done(&stats);
        if buf.len() != 24 {
            return prop::that(false, format!("JobDone must be 24 bytes, got {}", buf.len()));
        }
        match decode_job_done(&buf) {
            Ok(back) if back == stats => {}
            other => return prop::that(false, format!("roundtrip mangled: {other:?}")),
        }
        let cut = rng.below(24);
        prop::that(
            decode_job_done(&buf[..cut]).is_err(),
            format!("{cut}-byte prefix accepted"),
        )
    });
}
