//! Figure 2(a) regenerator: speedup vs worker count on the four (scaled)
//! datasets for LR + elastic net.
//!
//! Protocol follows §7.3: run pSCOPE to a fixed suboptimality gap with
//! p ∈ {1, 2, 4, 8} workers; Speedup(p) = T(1)/T(p). Time axis is the
//! cluster-equivalent clock: per epoch, the slowest worker's *thread-CPU*
//! compute time + master time + modeled 10 GbE wire time (this image has a
//! single core, so raw wall time cannot show parallelism; see DESIGN.md §4).
//! M = n/p (one local data pass) — the paper's full-size regime, where the
//! inner chains saturate and per-epoch progress is p-independent.
//! The paper reports "promising" (near-linear) speedup to p = 8.

use pscope::bench_util::{bench_spec, Table};
use pscope::config::{Model, PscopeConfig};
use pscope::coordinator::train_with;
use pscope::loss::Objective;
use pscope::net::NetModel;
use pscope::optim::fista::reference_optimum;
use pscope::partition::Partitioner;

fn main() {
    let full = std::env::var("PSCOPE_BENCH_SCALE").as_deref() == Ok("full");
    // geometry-preserving specs (see bench_spec); n boosted so that even at
    // p = 8 a single local pass saturates each worker's inner chain — the
    // precondition for parallel speedup (see DESIGN.md §4 on why the
    // cluster-equivalent clock, not raw wall time, carries this figure)
    let boost = |mut s: pscope::data::synth::SynthSpec| {
        s.n *= if full { 4 } else { 3 };
        s
    };
    let datasets = [
        ("cov_like", boost(bench_spec("cov_like", false))),
        ("rcv1_like", boost(bench_spec("rcv1_like", false))),
        ("avazu_like", boost(bench_spec("avazu_like", false))),
        ("kdd2012_like", boost(bench_spec("kdd2012_like", false))),
    ];
    let tol = 1e-6;

    let mut table = Table::new(
        "fig2a speedup (LR, stop at gap<=1e-6)",
        &["dataset", "p", "time(s)", "epochs", "speedup"],
    );
    for (name, spec) in &datasets {
        let ds = spec.generate();
        let base_cfg = PscopeConfig::for_dataset(name, Model::Logistic);
        // conditioning for saturation at laptop scale (see example docs)
        let reg = pscope::loss::Reg { lam1: 1e-3, ..base_cfg.reg };
        let obj = Objective::new(&ds, Model::Logistic.loss(), reg);
        let opt = reference_optimum(&obj, 3000);
        let mut t1 = f64::NAN;
        for p in [1usize, 2, 4, 8] {
            let cfg = PscopeConfig {
                p,
                outer_iters: if full { 80 } else { 50 },
                m_inner: ds.n() / p,
                c_eta: 1.0,
                reg,
                seed: 42,
                target_objective: opt.objective,
                tol,
                ..base_cfg.clone()
            };
            let part = Partitioner::Uniform.split(&ds, p, 7);
            let out = train_with(&ds, &part, &cfg, None, NetModel::ten_gbe()).unwrap();
            let t = out
                .trace
                .time_to_gap(opt.objective, tol)
                .unwrap_or(f64::INFINITY);
            if p == 1 {
                t1 = t;
            }
            let cells = [
                name.to_string(),
                p.to_string(),
                if t.is_finite() { format!("{t:.3}") } else { "—".into() },
                out.epochs_run.to_string(),
                format!("{:.2}", t1 / t),
            ];
            if t.is_finite() {
                table.row_timed(&cells, t);
            } else {
                table.row(&cells);
            }
        }
    }
    table.emit();
    println!("paper shape: near-linear speedup to p=8 on all four datasets.");
}
