//! Table 2 regenerator: wall time to a 1e-3-suboptimal solution, pSCOPE vs
//! DBCD, for LR (elastic net) and Lasso on cov-like and rcv1-like data.
//!
//! Paper's numbers (their testbed):
//!
//! |       |      | pSCOPE | DBCD   |
//! |-------|------|--------|--------|
//! | LR    | cov  | 0.32 s | 822 s  |
//! |       | rcv1 | 3.78 s | >1000 s|
//! | Lasso | cov  | 0.06 s | 81.9 s |
//! |       | rcv1 | 3.09 s | >1000 s|
//!
//! The *shape* to reproduce: DBCD is 2–4 orders of magnitude slower; the
//! bench caps DBCD's budget and reports `>cap` exactly as the paper does.

use pscope::baselines::{dbcd::Dbcd, pscope::PScope, BaselineOpts, DistSolver};
use pscope::bench_util::{bench_spec, Table};
use pscope::config::Model;
use pscope::data::synth;
use pscope::loss::Objective;
use pscope::net::NetModel;
use pscope::optim::fista::reference_optimum;

fn main() {
    let full = std::env::var("PSCOPE_BENCH_SCALE").as_deref() == Ok("full");
    let datasets = [
        ("cov_like", bench_spec("cov_like", full)),
        ("rcv1_like", bench_spec("rcv1_like", full)),
    ];
    let dbcd_cap_s = if full { 300.0 } else { 60.0 };

    let mut table = Table::new(
        "table2 time to 1e-3-suboptimal (s)",
        &["model", "dataset", "pSCOPE", "DBCD", "ratio"],
    );
    for model in [Model::Logistic, Model::Lasso] {
        for (name, spec) in &datasets {
            let spec = if model == Model::Lasso {
                spec.clone().with_task(synth::Task::Regression)
            } else {
                spec.clone()
            };
            let ds = spec.generate();
            let cfg = pscope::config::PscopeConfig::for_dataset(name, model);
            let reg = pscope::loss::Reg { lam1: cfg.reg.lam1.max(1e-5), ..cfg.reg };
            let obj = Objective::new(&ds, model.loss(), reg);
            let opt = reference_optimum(&obj, 8000);
            let run = |solver: &dyn DistSolver, cap: f64, rounds: usize| {
                let opts = BaselineOpts {
                    p: 8,
                    seed: 42,
                    max_rounds: rounds,
                    max_total_s: cap,
                    net: NetModel::ten_gbe(),
                    record_every: 1,
                    target_objective: opt.objective,
                    tol: 1e-3,
                };
                solver.run(&ds, model, reg, &opts).time_to_gap(opt.objective, 1e-3)
            };
            // grid-tuned step for pSCOPE (paper protocol)
            let t_ps = [0.5f64, 2.0, 6.0]
                .iter()
                .filter_map(|&c| run(&PScope { c_eta: c, ..Default::default() }, 120.0, 200))
                .min_by(|a, b| a.partial_cmp(b).unwrap());
            let t_db = run(&Dbcd::default(), dbcd_cap_s, 100_000);
            let fmt = |t: Option<f64>, cap: f64| {
                t.map(|v| format!("{v:.3}")).unwrap_or(format!(">{cap:.0}"))
            };
            let ratio = match (t_ps, t_db) {
                (Some(a), Some(b)) => format!("{:.0}x", b / a),
                (Some(a), None) => format!(">{:.0}x", dbcd_cap_s / a),
                _ => "—".into(),
            };
            let cells = [
                model.name().to_string(),
                name.to_string(),
                fmt(t_ps, 120.0),
                fmt(t_db, dbcd_cap_s),
                ratio,
            ];
            // primary timing for the JSON trajectory: pSCOPE's time-to-gap
            match t_ps {
                Some(t) => table.row_timed(&cells, t),
                None => table.row(&cells),
            }
        }
    }
    table.emit();
    println!("paper shape: DBCD 2-4 orders of magnitude slower than pSCOPE on every row.");
}
