//! Micro-benchmarks of the hot paths (EXPERIMENTS.md §Perf input):
//!
//! * §6 lazy engine vs naive dense engine — the recovery-rule speedup
//!   (E6), plus the conditional-statement reduction counter;
//! * workspace reuse: the same lazy epoch with a fresh allocation per
//!   epoch vs the zero-allocation [`EpochWorkspace`] path;
//! * `lazy_advance` scalar cost (phase decomposition, O(log k));
//! * shard-gradient kernel, serial and parallel (the deterministic blocked
//!   reduction — bit-exact at every thread count);
//! * the `--precision fast` tier (DESIGN.md §14): the f32 dense inner
//!   epoch and f32 blocked gradient vs their exact-f64 twins — the
//!   two-tier rows EXPERIMENTS.md walks through;
//! * coordinator protocol overhead: one full epoch at M = 0 (pure
//!   broadcast/reduce) vs the per-epoch compute at the default M;
//! * PJRT inner-epoch artifact execution (when `artifacts/` exists).
//!
//! Pass `--quick` (the CI bench-smoke mode) for 1 sample on a tiny
//! instance — enough to exercise every path and emit the
//! `bench_out/BENCH_*.json` trajectory point without burning minutes.

use pscope::bench_util::{human_time, time_fn, Table};
use pscope::config::{Model, PscopeConfig, WorkerBackend};
use pscope::coordinator::train_with;
use pscope::data::synth;
use pscope::loss::{Objective, Reg};
use pscope::net::NetModel;
use pscope::optim::lazy::{lazy_advance, lazy_inner_epoch, lazy_inner_epoch_ws, LazyStats};
use pscope::optim::svrg::{dense_inner_epoch, dense_inner_epoch_fast_ws};
use pscope::optim::workspace::EpochWorkspace;
use pscope::partition::Partitioner;
use pscope::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let s = |n: usize| if quick { 1 } else { n };
    let mut table = Table::new("micro hotpath", &["benchmark", "median", "notes"]);

    // ---- lazy vs dense inner epoch on rcv1-like sparsity ----
    // quick n stays above 2×GRAD_BLOCK_ROWS so the smoke run still drives
    // the multi-block parallel gradient path, not just the serial kernel
    let ds = synth::rcv1_like(42).with_n(if quick { 2500 } else { 4000 }).generate();
    let reg = Reg { lam1: 1e-4, lam2: 1e-5 };
    let obj = Objective::new(&ds, pscope::loss::Loss::Logistic, reg);
    let w = vec![0.01; ds.d()];
    let z = obj.data_grad(&w);
    let eta = 0.5 / obj.smoothness();
    let m = ds.n();
    let t_lazy = time_fn(s(1), s(7), || {
        let mut rng = Rng::new(7);
        let mut stats = LazyStats::default();
        std::hint::black_box(lazy_inner_epoch(
            &ds, pscope::loss::Loss::Logistic, &w, &z, eta, reg, m, &mut rng,
            &mut stats,
        ));
    });
    let t_dense = time_fn(s(1), s(3), || {
        let mut rng = Rng::new(7);
        std::hint::black_box(dense_inner_epoch(
            &ds, pscope::loss::Loss::Logistic, &w, &z, eta, reg, m, &mut rng,
        ));
    });
    let mut stats = LazyStats::default();
    let mut rng = Rng::new(7);
    let _ = lazy_inner_epoch(
        &ds, pscope::loss::Loss::Logistic, &w, &z, eta, reg, m, &mut rng, &mut stats,
    );
    table.row_stats(
        &[
            format!("lazy inner epoch (M={m}, d={})", ds.d()),
            human_time(t_lazy.median),
            format!(
                "{:.1} Msteps/s, {:.2}% coord work saved",
                m as f64 / t_lazy.median / 1e6,
                100.0 * stats.savings()
            ),
        ],
        &t_lazy,
    );
    table.row_stats(
        &[
            format!("dense inner epoch (M={m}, d={})", ds.d()),
            human_time(t_dense.median),
            format!("recovery-rule speedup {:.1}x", t_dense.median / t_lazy.median),
        ],
        &t_dense,
    );

    // ---- fast tier: the same dense epoch through the f32 kernels ----
    let mut ws_fast = EpochWorkspace::new();
    let t_fast = time_fn(s(1), s(3), || {
        let mut rng = Rng::new(7);
        std::hint::black_box(dense_inner_epoch_fast_ws(
            &ds, pscope::loss::Loss::Logistic, &w, &z, eta, reg, m, &mut rng,
            &mut ws_fast,
        ));
    });
    table.row_stats(
        &[
            format!("dense inner epoch, fast tier (M={m})"),
            human_time(t_fast.median),
            format!(
                "{:.2}x vs exact dense (--precision fast, tolerance-pinned)",
                t_dense.median / t_fast.median
            ),
        ],
        &t_fast,
    );

    // ---- workspace reuse: zero-allocation steady state ----
    let mut ws = EpochWorkspace::new();
    let t_ws = time_fn(s(1), s(7), || {
        let mut rng = Rng::new(7);
        let mut stats = LazyStats::default();
        std::hint::black_box(lazy_inner_epoch_ws(
            &ds, pscope::loss::Loss::Logistic, &w, &z, eta, reg, m, &mut rng,
            &mut stats, &mut ws,
        ));
    });
    table.row_stats(
        &[
            "lazy epoch, reused EpochWorkspace".into(),
            human_time(t_ws.median),
            format!(
                "{:.1}% vs fresh-alloc epoch, {} alloc events total",
                100.0 * t_ws.median / t_lazy.median,
                ws.allocations()
            ),
        ],
        &t_ws,
    );

    // ---- lazy_advance scalar ----
    let t_adv = time_fn(s(10), s(21), || {
        let mut acc = 0.0;
        for i in 0..10_000 {
            acc += lazy_advance(1.0 + (i % 7) as f64, 1000 + i % 97, 1e-4, 2e-5, 1e-5);
        }
        std::hint::black_box(acc);
    });
    table.row_stats(
        &[
            "lazy_advance x10k (k~1000)".into(),
            human_time(t_adv.median),
            format!("{:.0} ns/advance", t_adv.median / 10_000.0 * 1e9),
        ],
        &t_adv,
    );

    // ---- prox kernels: per-regularizer vector prox over a d-sized
    // iterate (the dense engine's per-step cost floor; tracked in
    // BENCH_*.json so prox cost per regularizer regresses visibly) ----
    {
        use pscope::loss::ProxReg;
        let dprox = if quick { 10_000 } else { 200_000 };
        let mut rngp = Rng::new(3);
        let base: Vec<f64> = (0..dprox).map(|_| rngp.normal()).collect();
        let step = 0.05;
        for (name, preg) in [
            ("l1", ProxReg::L1 { lam: 1e-3 }),
            ("elasticnet", ProxReg::ElasticNet { lam1: 1e-4, lam2: 1e-3 }),
            ("group(8)", ProxReg::GroupLasso { lam: 1e-3, group: 8 }),
            ("nonneg", ProxReg::NonnegL1 { lam: 1e-3 }),
        ] {
            // prox applied in place, repeatedly, with NO reset inside the
            // timed region (a d-sized memcpy would be ~half the measured
            // time). The threshold is tiny relative to the N(0,1) values,
            // so the value/branch profile stays stable across samples;
            // nonneg's first application zeroes the negative half — a
            // transient the warmup iterations absorb before timing.
            let mut buf = base.clone();
            let t_prox = time_fn(s(3), s(11), || {
                preg.prox_vec(&mut buf, step);
                std::hint::black_box(&buf);
            });
            table.row_stats(
                &[
                    format!("prox kernel {name} (d={dprox})"),
                    human_time(t_prox.median),
                    format!("{:.2} Gcoord/s", dprox as f64 / t_prox.median / 1e9),
                ],
                &t_prox,
            );
        }
    }

    // ---- shard gradient pass: serial vs parallel blocked reduction ----
    let mut g = vec![0.0; ds.d()];
    let mut scratch = Vec::new();
    let t_grad = time_fn(s(1), s(9), || {
        obj.shard_grad_sum_into(&w, &mut g, 1, &mut scratch);
        std::hint::black_box(&g);
    });
    table.row_stats(
        &[
            format!("shard grad serial (nnz={})", ds.nnz()),
            human_time(t_grad.median),
            format!("{:.0} Mnnz/s", ds.nnz() as f64 / t_grad.median / 1e6),
        ],
        &t_grad,
    );
    let mut t_par_last = t_grad;
    for threads in [2usize, 4] {
        let t_par = time_fn(s(1), s(9), || {
            obj.shard_grad_sum_into(&w, &mut g, threads, &mut scratch);
            std::hint::black_box(&g);
        });
        table.row_stats(
            &[
                format!("shard grad parallel t={threads}"),
                human_time(t_par.median),
                format!(
                    "{:.2}x vs serial (bit-exact, 1024-row blocks)",
                    t_grad.median / t_par.median
                ),
            ],
            &t_par,
        );
        t_par_last = t_par;
    }

    // ---- fast tier: the same blocked gradient through the f32 kernels ----
    {
        let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let mut scratch32: Vec<f32> = Vec::new();
        for (threads, t_exact) in [(1usize, t_grad), (4usize, t_par_last)] {
            let t_fg = time_fn(s(1), s(9), || {
                pscope::loss::shard_grad_sum_blocked_f32(
                    &ds,
                    pscope::loss::Loss::Logistic,
                    &w32,
                    &mut g,
                    threads,
                    &mut scratch32,
                );
                std::hint::black_box(&g);
            });
            table.row_stats(
                &[
                    format!("shard grad fast tier t={threads}"),
                    human_time(t_fg.median),
                    format!(
                        "{:.2}x vs exact t={threads} (--precision fast, f64 carry)",
                        t_exact.median / t_fg.median
                    ),
                ],
                &t_fg,
            );
        }
    }

    // ---- coordinator protocol overhead ----
    let part = Partitioner::Uniform.split(&ds, 8, 7);
    let mk = |m_inner: usize| PscopeConfig {
        p: 8,
        outer_iters: 3,
        m_inner,
        reg,
        seed: 42,
        record_every: 100,
        ..PscopeConfig::for_dataset("rcv1_like", Model::Logistic)
    };
    let t_proto = time_fn(s(1), s(5), || {
        let cfg = mk(1); // M=1: epoch cost ~= pure protocol + grad pass
        std::hint::black_box(train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap());
    });
    let t_epoch = time_fn(s(1), s(5), || {
        let cfg = mk(0); // default M = 2n/p
        std::hint::black_box(train_with(&ds, &part, &cfg, None, NetModel::zero()).unwrap());
    });
    table.row_stats(
        &[
            "3 epochs, M=1 (protocol+grad)".into(),
            human_time(t_proto.median),
            "coordination floor".into(),
        ],
        &t_proto,
    );
    table.row_stats(
        &[
            "3 epochs, M=2n/p (default)".into(),
            human_time(t_epoch.median),
            format!(
                "coordination overhead {:.1}%",
                100.0 * t_proto.median / t_epoch.median
            ),
        ],
        &t_epoch,
    );

    // ---- warm vs cold start along a λ path (the serve-pool payoff) ----
    // Solve at λ_hi, then solve λ_lo twice under the same half-gap
    // early-stop protocol `pscope serve` uses: cold from zeros, warm from
    // the λ_hi iterate (train_with_opts ships the exact bits, like the
    // JobSetup frame). The epoch counts land in BENCH_*.json so the
    // λ-path speedup regresses visibly.
    {
        use pscope::coordinator::train_with_opts;
        use pscope::optim::fista::reference_optimum;
        let zero_w = vec![0.0; ds.d()];
        let mk = |lam1: f64| {
            let r = Reg { lam1, lam2: 1e-5 };
            let mut cfg = PscopeConfig {
                p: 8,
                outer_iters: 40,
                reg: r,
                seed: 42,
                record_every: 1,
                ..PscopeConfig::for_dataset("rcv1_like", Model::Logistic)
            };
            let obj = Objective::new(&ds, pscope::loss::Loss::Logistic, r);
            let opt = reference_optimum(&obj, if quick { 5_000 } else { 50_000 });
            cfg.target_objective = opt.objective;
            cfg.tol = 0.5 * (obj.value(&zero_w) - opt.objective);
            cfg
        };
        let cfg_hi = mk(1e-3);
        let cfg_lo = mk(1e-4);
        let w_hi = train_with(&ds, &part, &cfg_hi, None, NetModel::zero()).unwrap().w;
        let cold = train_with(&ds, &part, &cfg_lo, None, NetModel::zero()).unwrap();
        let warm =
            train_with_opts(&ds, &part, &cfg_lo, None, NetModel::zero(), Some(&w_hi)).unwrap();
        let t_cold = time_fn(s(1), s(3), || {
            std::hint::black_box(train_with(&ds, &part, &cfg_lo, None, NetModel::zero()).unwrap());
        });
        let t_warm = time_fn(s(1), s(3), || {
            std::hint::black_box(
                train_with_opts(&ds, &part, &cfg_lo, None, NetModel::zero(), Some(&w_hi)).unwrap(),
            );
        });
        table.row_stats(
            &[
                "λ-path cold start (λ=1e-4, half-gap stop)".into(),
                human_time(t_cold.median),
                format!("{} epochs from zeros", cold.epochs_run),
            ],
            &t_cold,
        );
        table.row_stats(
            &[
                "λ-path warm start (w0 from λ=1e-3)".into(),
                human_time(t_warm.median),
                format!(
                    "{} epochs, {:.1}x vs cold",
                    warm.epochs_run,
                    t_cold.median / t_warm.median
                ),
            ],
            &t_warm,
        );
    }

    // ---- sparse wire codec (SPEC_VERSION 7): arm cost + bytes/epoch ----
    // Encode/decode ns for both arms of the v7 vector part, then the
    // meter's payoff: total wire bytes per epoch, dense vs `--wire auto`,
    // along a λ ramp (heavier l1 ⇒ sparser iterates ⇒ smaller frames).
    {
        use pscope::config::WireMode;
        use pscope::coordinator::protocol::ToWorker;
        use pscope::net::frame;

        let dcodec = if quick { 2_000 } else { 50_000 };
        let mut rngw = Rng::new(11);
        let mut sparse_w = vec![0.0f64; dcodec];
        for _ in 0..dcodec / 100 {
            let i = rngw.below(dcodec);
            sparse_w[i] = rngw.normal();
        }
        let dense_w: Vec<f64> = (0..dcodec).map(|_| rngw.normal()).collect();
        for (name, v, mode) in [
            ("dense arm", &dense_w, WireMode::Dense),
            ("sparse arm (~1% nnz)", &sparse_w, WireMode::Auto),
        ] {
            let msg = ToWorker::Broadcast { epoch: 1, w: v.clone() };
            let t_enc = time_fn(s(3), s(11), || {
                std::hint::black_box(frame::encode_to_worker_mode(&msg, mode));
            });
            let buf = frame::encode_to_worker_mode(&msg, mode);
            let t_dec = time_fn(s(3), s(11), || {
                std::hint::black_box(frame::decode_to_worker(&buf).unwrap());
            });
            table.row_stats(
                &[
                    format!("wire encode {name} (d={dcodec})"),
                    human_time(t_enc.median),
                    format!("decode {}, {} B/frame", human_time(t_dec.median), buf.len()),
                ],
                &t_enc,
            );
        }

        for lam1 in [1e-4f64, 1e-3, 1e-2] {
            let mkw = |wire: WireMode| PscopeConfig {
                p: 8,
                outer_iters: 3,
                reg: Reg { lam1, lam2: 1e-5 },
                seed: 42,
                record_every: 100,
                wire,
                ..PscopeConfig::for_dataset("rcv1_like", Model::Logistic)
            };
            let dense_run =
                train_with(&ds, &part, &mkw(WireMode::Dense), None, NetModel::zero()).unwrap();
            let auto_run =
                train_with(&ds, &part, &mkw(WireMode::Auto), None, NetModel::zero()).unwrap();
            let (db, ab) = (dense_run.comm.0, auto_run.comm.0);
            table.row(&[
                format!("wire bytes/epoch λ1={lam1:.0e} (p=8)"),
                format!("{} B auto", ab / 3),
                format!("{} B dense — auto is {:.1}%", db / 3, 100.0 * ab as f64 / db as f64),
            ]);
        }
    }

    // ---- PJRT artifact execution ----
    if std::path::Path::new("artifacts/manifest.json").exists() && !quick {
        let dsd = synth::cov_like(42).with_n(1500).generate();
        let partd = Partitioner::Uniform.split(&dsd, 1, 7);
        let cfg = PscopeConfig {
            p: 1,
            outer_iters: 2,
            m_inner: 512,
            reg,
            backend: WorkerBackend::Xla,
            seed: 42,
            record_every: 100,
            ..PscopeConfig::for_dataset("cov_like", Model::Logistic)
        };
        let t_xla = time_fn(1, 3, || {
            std::hint::black_box(
                train_with(&dsd, &partd, &cfg, Some("artifacts".into()), NetModel::zero())
                    .unwrap(),
            );
        });
        table.row_stats(
            &[
                "2 epochs via PJRT artifact (2048x64, M=512)".into(),
                human_time(t_xla.median),
                "includes per-run client + compile".into(),
            ],
            &t_xla,
        );
    } else {
        table.row(&[
            "PJRT artifact exec".into(),
            "skipped".into(),
            if quick { "--quick mode".into() } else { "run `make artifacts`".into() },
        ]);
    }

    table.emit();
}
