//! Figure 1 regenerator: objective gap vs time for LR+elastic-net and
//! Lasso on the four (scaled) datasets — pSCOPE vs FISTA, mOWL-QN, DFAL,
//! AsyProx-SVRG, ProxCOCOA+ (+ dpSGD as an extra point of reference).
//!
//! Prints, per (dataset, model) panel, each solver's time to reach the
//! 1e-3 / 1e-5 suboptimality gaps plus the best gap achieved inside the
//! budget, and dumps every convergence trace under `bench_out/fig1_*.csv`
//! so the actual curves can be plotted. The paper's claim to reproduce is
//! the *shape*: pSCOPE reaches any target gap first on every panel, with
//! AsyProx-SVRG only competitive on the two smaller datasets.
//!
//! Scale: `PSCOPE_BENCH_SCALE=full` runs bigger instances; default `small`
//! keeps the full suite under a few minutes.

use pscope::baselines::{all_baselines, BaselineOpts, DistSolver};
use pscope::bench_util::{bench_spec, Table};
use pscope::config::Model;
use pscope::data::synth;
use pscope::loss::Objective;
use pscope::net::NetModel;
use pscope::optim::fista::reference_optimum;

fn main() {
    let full = std::env::var("PSCOPE_BENCH_SCALE").as_deref() == Ok("full");
    let datasets = [
        ("cov_like", bench_spec("cov_like", full)),
        ("rcv1_like", bench_spec("rcv1_like", full)),
        ("avazu_like", bench_spec("avazu_like", full)),
        ("kdd2012_like", bench_spec("kdd2012_like", full)),
    ];

    for model in [Model::Logistic, Model::Lasso] {
        for (name, spec) in &datasets {
            let spec = if model == Model::Lasso {
                spec.clone().with_task(synth::Task::Regression)
            } else {
                spec.clone()
            };
            let ds = spec.generate();
            let cfg = pscope::config::PscopeConfig::for_dataset(name, model);
            // lam1 floor: see bench_spec docs
            let reg = pscope::loss::Reg { lam1: cfg.reg.lam1.max(1e-5), ..cfg.reg };
            let obj = Objective::new(&ds, model.loss(), reg);
            let opt = reference_optimum(&obj, 8000);
            if !opt.converged {
                eprintln!("warning: reference for {name}/{} not fully converged", model.name());
            }
            let p0 = obj.value(&vec![0.0; ds.d()]);

            let mut table = Table::new(
                &format!("fig1 {} {} (n={} d={})", model.name(), name, ds.n(), ds.d()),
                &["solver", "t_gap1e-3(s)", "t_gap1e-5(s)", "best_gap", "rounds", "comm(MB)"],
            );
            // the paper grid-tunes every method's step size per dataset;
            // pSCOPE is the only roster member with a free step parameter
            // (FISTA/CoCoA/DBCD use exact curvature, OWL-QN line-searches),
            // so sweep its c_eta and report the best, as the paper does.
            let pscope_variants = [0.5f64, 2.0, 6.0];
            for solver in all_baselines() {
                // the paper omits AsyProx-SVRG on the two big datasets
                // (too slow); same protocol here
                let big = name.contains("avazu") || name.contains("kdd");
                if solver.name() == "AsyProx-SVRG" && big {
                    table.row(&[
                        solver.name().into(),
                        "—".into(),
                        "—".into(),
                        "(skipped: too slow on high-d, as in the paper)".into(),
                        "—".into(),
                        "—".into(),
                    ]);
                    continue;
                }
                let opts = BaselineOpts {
                    p: 8,
                    seed: 42,
                    max_rounds: if full { 400 } else { 150 },
                    max_total_s: if full { 120.0 } else { 30.0 },
                    net: NetModel::ten_gbe(),
                    record_every: 1,
                    target_objective: opt.objective,
                    tol: 1e-7,
                };
                let trace = if solver.name() == "pSCOPE" {
                    pscope_variants
                        .iter()
                        .map(|&c| {
                            pscope::baselines::pscope::PScope { c_eta: c, ..Default::default() }
                                .run(&ds, model, reg, &opts)
                        })
                        .min_by(|a, b| {
                            let key = |t: &pscope::metrics::Trace| {
                                (
                                    t.time_to_gap(opt.objective, 1e-5).unwrap_or(f64::INFINITY),
                                    t.time_to_gap(opt.objective, 1e-3).unwrap_or(f64::INFINITY),
                                    t.last_objective(),
                                )
                            };
                            key(a).partial_cmp(&key(b)).unwrap()
                        })
                        .unwrap()
                } else {
                    solver.run(&ds, model, reg, &opts)
                };
                let fmt_t = |tol: f64| {
                    trace
                        .time_to_gap(opt.objective, tol)
                        .map(|t| format!("{t:.3}"))
                        .unwrap_or_else(|| "—".into())
                };
                let best = trace
                    .points
                    .iter()
                    .map(|pt| pt.objective - opt.objective)
                    .fold(p0 - opt.objective, f64::min);
                let last = trace.points.last().unwrap();
                let cells = [
                    solver.name().to_string(),
                    fmt_t(1e-3),
                    fmt_t(1e-5),
                    format!("{best:.2e}"),
                    format!("{}", last.epoch),
                    format!("{:.2}", last.comm_bytes as f64 / 1e6),
                ];
                // primary timing for the JSON trajectory: time to the 1e-3 gap
                match trace.time_to_gap(opt.objective, 1e-3) {
                    Some(t) => table.row_timed(&cells, t),
                    None => table.row(&cells),
                }
                // dump the curve
                if std::fs::create_dir_all("bench_out").is_ok() {
                    let path = format!(
                        "bench_out/fig1_{}_{}_{}.csv",
                        model.name(),
                        name,
                        solver.name().replace(['+', '-'], "_")
                    );
                    if let Ok(f) = std::fs::File::create(&path) {
                        let _ = trace.write_csv(f, opt.objective);
                    }
                }
            }
            table.emit();
        }
    }
    println!("expected shape: pSCOPE reaches each gap first on every panel;");
    println!("ProxCOCOA+/FISTA next; dpSGD/DFAL trail; AsyProx-SVRG only viable on low-d data.");
}
