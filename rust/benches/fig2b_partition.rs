//! Figure 2(b) regenerator: effect of the data partition (§7.4) — train LR
//! under π* (replicated), π₁ (uniform), π₂ (75/25 label skew), π₃ (full
//! label separation) **plus the engineered partition** on cov-like and
//! rcv1-like data, and additionally measure the paper's goodness constant
//! γ̂(π; ε) so the theory link ("better partition ⇒ faster convergence",
//! Theorem 2) is checked quantitatively, not just visually.
//!
//! Paper shape: π* best, π₁ ≈ π*, π₂ worse, π₃ worst (can stall). The
//! engineered rows are this repo's extension (DESIGN.md §8): on the
//! class-skewed data that makes π₂/π₃ bad, the sketch→assign→refine
//! search should land at γ̂ ≤ π₁ — the theory's production lever.

use pscope::bench_util::Table;
use pscope::config::{Model, PscopeConfig};
use pscope::coordinator::train_with;
use pscope::data::synth;
use pscope::loss::Objective;
use pscope::net::NetModel;
use pscope::optim::fista::reference_optimum;
use pscope::partition::goodness::{analyze, GoodnessOpts};
use pscope::partition::Partitioner;

fn main() {
    let full = std::env::var("PSCOPE_BENCH_SCALE").as_deref() == Ok("full");
    // class_scale > 1 reproduces the class-conditional curvature real data
    // (cov, rcv1) carries; symmetric synthetic data would let the per-worker
    // biases cancel in the master average (see the SynthSpec::class_scale
    // field docs and DESIGN.md §5)
    // rcv1 at reduced n must keep n >> d or the per-worker logistic
    // subproblems are separable/degenerate; shrink d along with n.
    let rcv1_small = synth::SynthSpec {
        d: if full { 4000 } else { 1000 },
        ..synth::rcv1_like(42)
    };
    let datasets = [
        ("cov_like", synth::cov_like(42).with_n(if full { 8000 } else { 2500 }).with_class_scale(3.0)),
        ("rcv1_like", rcv1_small.with_n(if full { 16_000 } else { 6000 }).with_class_scale(3.0)),
    ];
    let epochs = if full { 40 } else { 25 };

    let mut table = Table::new(
        "fig2b partition effect (LR)",
        &["dataset", "partition", "gamma_hat", "gap@5ep", "gap@end", "epochs_to_1e-5"],
    );
    for (name, spec) in &datasets {
        let ds = spec.generate();
        // goodness analysis needs many local FISTA solves; measure it on a
        // subsample for the big sets (γ is a distributional property)
        let ds_gamma = if ds.n() > 1500 {
            let rows: Vec<usize> = (0..ds.n()).step_by(ds.n() / 1200).collect();
            ds.select(&rows)
        } else {
            ds.clone()
        };
        let cfg0 = PscopeConfig::for_dataset(name, Model::Logistic);
        // slightly stronger ridge keeps the goodness subproblems and the
        // reference optimum well-conditioned at this reduced scale
        let reg = pscope::loss::Reg { lam1: 1e-4, ..cfg0.reg };
        let obj = Objective::new(&ds, Model::Logistic.loss(), reg);
        let opt = reference_optimum(&obj, 5000);
        let gopts = GoodnessOpts {
            local_iters: if full { 3000 } else { 1500 },
            ..GoodnessOpts::quick()
        };
        for strat in Partitioner::all_with_engineered() {
            let part_g = strat.split(&ds_gamma, 8, 3);
            let rep = analyze(&ds_gamma, &part_g, Model::Logistic.loss(), reg, &gopts);
            let part = strat.split(&ds, 8, 3);
            let cfg = PscopeConfig {
                p: 8,
                outer_iters: epochs,
                // Theorem-2 regime: inner epochs approach the local optima
                m_inner: 4 * ds.n(),
                c_eta: 1.0,
                reg,
                seed: 42,
                ..cfg0.clone()
            };
            let out = train_with(&ds, &part, &cfg, None, NetModel::ten_gbe()).unwrap();
            let gap_at = |ep: usize| {
                out.trace
                    .points
                    .iter()
                    .filter(|p| p.epoch <= ep)
                    .next_back()
                    .map(|p| p.objective - opt.objective)
                    .unwrap_or(f64::NAN)
            };
            let to_tol = out
                .trace
                .epochs_to_gap(opt.objective, 1e-5)
                .map(|e| e.to_string())
                .unwrap_or_else(|| format!(">{epochs}"));
            table.row(&[
                name.to_string(),
                part.tag.clone(),
                format!("{:.3e}", rep.gamma_hat),
                format!("{:.2e}", gap_at(5)),
                format!("{:.2e}", gap_at(epochs)),
                to_tol,
            ]);
            if std::fs::create_dir_all("bench_out").is_ok() {
                let path = format!("bench_out/fig2b_{}_{}.csv", name, part.tag.replace('*', "star"));
                if let Ok(f) = std::fs::File::create(&path) {
                    let _ = out.trace.write_csv(f, opt.objective);
                }
            }
        }
    }
    table.emit();
    println!("paper shape: gamma and convergence order agree: pi* <= pi1 << pi2 << pi3.");
    println!("repo extension: engineered <= pi1 on both datasets (DESIGN.md §8).");
}
